"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (Griffin recurrent block):
  x -> norm -> [branch A: linear -> causal conv1d(w=4) -> RG-LRU]
            -> [branch B: linear -> gelu]
  y = out_proj(A * B) + x

RG-LRU: r_t = sigma(W_r u_t), i_t = sigma(W_i u_t),
        log a_t = -c * softplus(L) * r_t        (c = 8)
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
Training/prefill use an associative scan (elementwise linear recurrence);
decode is one step. Decode state: (h, conv tail of width-1 inputs).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, norm_init, apply_norm

F32 = jnp.float32
_C = 8.0


def rglru_init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    # Lambda init so a^(1/c) ~ U[0.9, 0.999] (griffin appendix)
    u = jax.random.uniform(ks[0], (d,), F32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))                      # softplus^-1(-log u)
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "in_a": dense_init(ks[1], d, d, dtype),
        "in_b": dense_init(ks[2], d, d, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, d), F32)
                   / math.sqrt(cfg.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "wr": dense_init(ks[4], d, d, dtype),
        "wi": dense_init(ks[5], d, d, dtype),
        "lam": lam,
        "out": dense_init(ks[6], d, d, dtype),
    }


def rglru_state_shape(cfg, B):
    d = cfg.d_model
    return {"h": (B, d), "conv": (B, cfg.conv_width - 1, d)}


def rglru_init_state(cfg, B, dtype=F32):
    sh = rglru_state_shape(cfg, B)
    return {"h": jnp.zeros(sh["h"], F32), "conv": jnp.zeros(sh["conv"], dtype)}


def _causal_conv(u, w, b, tail):
    """u: (B,S,d); w: (K,d) depthwise. tail: (B,K-1,d) history."""
    K = w.shape[0]
    upad = jnp.concatenate([tail.astype(u.dtype), u], axis=1)  # (B,S+K-1,d)
    out = sum(upad[:, i:i + u.shape[1]] * w[i] for i in range(K))
    new_tail = upad[:, -(K - 1):] if K > 1 else tail
    return out + b, new_tail


def _rglru_scan(a_log, x_in, h0):
    """Elementwise linear recurrence via associative scan.

    a_log: (B,S,d) log decay; x_in: (B,S,d) input term; h0: (B,d).
    h_t = exp(a_log_t) h_{t-1} + x_in_t
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2
    # fold h0 into first element
    x0 = x_in.at[:, 0].add(jnp.exp(a_log[:, 0]) * h0)
    al, bl = jax.lax.associative_scan(combine, (a_log, x0), axis=1)
    return bl


def rglru_apply(p, x, cfg, state=None, decode=False):
    B, S, d = x.shape
    xn = apply_norm(p["norm"], x, cfg.norm)
    ua = xn @ p["in_a"]
    ub = jax.nn.gelu(xn @ p["in_b"])
    if state is None:
        state = rglru_init_state(cfg, B)
    u, new_tail = _causal_conv(ua, p["conv_w"], p["conv_b"], state["conv"])
    uf = u.astype(F32)
    r = jax.nn.sigmoid((u @ p["wr"]).astype(F32))
    i = jax.nn.sigmoid((u @ p["wi"]).astype(F32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(F32)) * r     # (B,S,d)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)
    if decode:
        assert S == 1
        h = jnp.exp(log_a[:, 0]) * state["h"] + gated[:, 0]
        hs = h[:, None]
        new_h = h
    else:
        hs = _rglru_scan(log_a, gated, state["h"])
        new_h = hs[:, -1]
    y = (hs.astype(x.dtype) * ub) @ p["out"]
    return x + y, {"h": new_h, "conv": new_tail}
