"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan with recurrent gate connections).

mLSTM is a gated linear recurrence C_t = f_t C_{t-1} + i_t k_t v_t^T with
exponential input gating and a max-stabilizer m. Training/prefill use a
chunkwise-parallel formulation (intra-chunk quadratic, inter-chunk state
carry) — linear in sequence length; decode is a single recurrent step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, norm_init, apply_norm

F32 = jnp.float32


# ------------------------------------------------------------------- mLSTM

def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    du = 2 * d
    H = cfg.num_heads
    ks = jax.random.split(key, 9)
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "up": dense_init(ks[0], d, 2 * du, dtype),            # -> (u, z-gate)
        "q": dense_init(ks[1], du, du, dtype),
        "k": dense_init(ks[2], du, du, dtype),
        "v": dense_init(ks[3], du, du, dtype),
        "wi": dense_init(ks[4], du, H, dtype, scale=0.01),
        "wf": dense_init(ks[5], du, H, dtype, scale=0.01),
        "bf": jnp.full((H,), 3.0, dtype),                     # forget bias > 0
        "bi": jnp.zeros((H,), dtype),
        "hnorm": norm_init(du, "rmsnorm", dtype),             # per-head group norm
        "down": dense_init(ks[6], du, d, dtype),
    }


def _mlstm_gates(u, p, H):
    i_raw = (u @ p["wi"]).astype(F32) + p["bi"].astype(F32)    # (B,S,H)
    f_raw = (u @ p["wf"]).astype(F32) + p["bf"].astype(F32)
    log_f = jax.nn.log_sigmoid(f_raw)
    return i_raw, log_f


def _mlstm_qkv(u, p, H):
    B, S, du = u.shape
    dh = du // H
    q = (u @ p["q"]).reshape(B, S, H, dh)
    k = (u @ p["k"]).reshape(B, S, H, dh)
    v = (u @ p["v"]).reshape(B, S, H, dh)
    return q, k, v, dh


def mlstm_state_shape(cfg, B):
    du = 2 * cfg.d_model
    H = cfg.num_heads
    dh = du // H
    return {"C": (B, H, dh, dh), "n": (B, H, dh), "m": (B, H)}


def mlstm_init_state(cfg, B, dtype=F32):
    sh = mlstm_state_shape(cfg, B)
    return {"C": jnp.zeros(sh["C"], F32), "n": jnp.zeros(sh["n"], F32),
            "m": jnp.full(sh["m"], -1e30, F32)}


def _mlstm_chunk_scan(q, k, v, i_raw, log_f, state, W):
    """Chunkwise-parallel mLSTM. q/k/v: (B,S,H,dh); gates (B,S,H)."""
    B, S, H, dh = q.shape
    assert S % W == 0, (S, W)
    nC = S // W
    scale = 1.0 / math.sqrt(dh)

    # reshape to chunks: (B, nC, W, H, ...)
    qc = q.reshape(B, nC, W, H, dh).astype(F32) * scale
    kc = k.reshape(B, nC, W, H, dh).astype(F32)
    vc = v.reshape(B, nC, W, H, dh).astype(F32)
    ic = i_raw.reshape(B, nC, W, H)
    lfc = log_f.reshape(B, nC, W, H)

    def chunk_step(carry, blk):
        Cb, nb, m0 = carry                    # (B,H,dh,dh), (B,H,dh), (B,H)
        qb, kb, vb, ib, lfb = blk             # (B,W,H,*)
        Bt = jnp.cumsum(lfb, axis=1)          # (B,W,H) decay from chunk start
        # intra-chunk log weights: D[t,s] = Bt[t]-Bt[s]+i[s], s<=t
        Dts = Bt[:, :, None, :] - Bt[:, None, :, :] + ib[:, None, :, :]
        tri = jnp.tril(jnp.ones((W, W), bool))
        Dts = jnp.where(tri[None, :, :, None], Dts, -jnp.inf)
        # stabilizer per target position
        m_intra = jnp.max(Dts, axis=2)                        # (B,W,H)
        m_t = jnp.maximum(m0[:, None] + Bt, m_intra)          # (B,W,H)
        # inter-chunk: q @ C_bar, scaled by exp(m0 + Bt - m_t)
        w_inter = jnp.exp(m0[:, None] + Bt - m_t)             # (B,W,H)
        num_inter = jnp.einsum("bwhd,bhde->bwhe", qb, Cb) * w_inter[..., None]
        den_inter = jnp.einsum("bwhd,bhd->bwh", qb, nb) * w_inter
        # intra-chunk
        P = jnp.exp(Dts - m_t[:, :, None, :])                 # (B,W,W,H)
        s_qk = jnp.einsum("bwhd,bshd->bwsh", qb, kb)
        A = s_qk * P
        num_intra = jnp.einsum("bwsh,bshe->bwhe", A, vb)
        den_intra = jnp.sum(A, axis=2)                        # (B,W,h)
        num = num_inter + num_intra
        den = den_inter + den_intra
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry to next chunk
        BW = Bt[:, -1]                                        # (B,H) total decay
        wk = BW[:, None] - Bt + ib                            # (B,W,H)
        m_next = jnp.maximum(m0 + BW, jnp.max(wk, axis=1))
        wk = jnp.exp(wk - m_next[:, None])
        C_next = (jnp.exp(m0 + BW - m_next)[..., None, None] * Cb
                  + jnp.einsum("bwh,bwhd,bwhe->bhde", wk, kb, vb))
        n_next = (jnp.exp(m0 + BW - m_next)[..., None] * nb
                  + jnp.einsum("bwh,bwhd->bhd", wk, kb))
        return (C_next, n_next, m_next), h

    xs = tuple(a.swapaxes(0, 1) for a in (qc, kc, vc, ic, lfc))
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (state["C"], state["n"], state["m"]), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    return h, {"C": Cf, "n": nf, "m": mf}


def mlstm_apply(p, x, cfg, state=None, decode=False):
    """x: (B,S,d). Returns (y, new_state)."""
    B, S, d = x.shape
    H = cfg.num_heads
    xn = apply_norm(p["norm"], x, cfg.norm)
    uz = xn @ p["up"]
    u, z = jnp.split(uz, 2, axis=-1)                          # (B,S,2d) each
    q, k, v, dh = _mlstm_qkv(u, p, H)
    i_raw, log_f = _mlstm_gates(u, p, H)
    if state is None:
        state = mlstm_init_state(cfg, B)
    if decode:
        assert S == 1
        qs, ks, vs = (t[:, 0].astype(F32) for t in (q, k, v))
        ib, lfb = i_raw[:, 0], log_f[:, 0]
        m_new = jnp.maximum(lfb + state["m"], ib)
        fp = jnp.exp(lfb + state["m"] - m_new)
        ip = jnp.exp(ib - m_new)
        C = fp[..., None, None] * state["C"] + ip[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", ks, vs)
        n = fp[..., None] * state["n"] + ip[..., None] * ks
        qs = qs / math.sqrt(dh)
        num = jnp.einsum("bhd,bhde->bhe", qs, C)
        den = jnp.einsum("bhd,bhd->bh", qs, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        h = h[:, None]                                        # (B,1,H,dh)
        new_state = {"C": C, "n": n, "m": m_new}
    else:
        W = min(cfg.mlstm_chunk, S)
        pad = (-S) % W
        if pad:
            # state-preserving pad: i = -inf (no input), log_f = 0 (no decay)
            zkv = ((0, 0), (0, pad), (0, 0), (0, 0))
            q, k, v = (jnp.pad(t, zkv) for t in (q, k, v))
            i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                            constant_values=-1e30)
            log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        h, new_state = _mlstm_chunk_scan(q, k, v, i_raw, log_f, state, W)
        if pad:
            h = h[:, :S]
    hflat = h.reshape(B, S, H * dh).astype(x.dtype)
    hflat = apply_norm(p["hnorm"], hflat, "rmsnorm")
    out = (hflat * jax.nn.silu(z)) @ p["down"]
    return x + out, new_state


# -------------------------------------------------------------------- sLSTM

def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ff = -(-int(4 * d / 3) // 16) * 16            # shard-friendly multiple of 16
    ks = jax.random.split(key, 6)
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "w": dense_init(ks[0], d, 4 * d, dtype),              # i,f,z,o
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh), F32)
              / math.sqrt(dh)).astype(dtype),                 # recurrent (block-diag)
        "b": jnp.concatenate([jnp.zeros((d,), dtype),
                              jnp.full((d,), 3.0, dtype),     # forget bias
                              jnp.zeros((2 * d,), dtype)]),
        "ffn_norm": norm_init(d, cfg.norm, dtype),
        "ff_gate": dense_init(ks[2], d, ff, dtype),
        "ff_up": dense_init(ks[3], d, ff, dtype),
        "ff_down": dense_init(ks[4], ff, d, dtype),
    }


def slstm_state_shape(cfg, B):
    d = cfg.d_model
    return {"c": (B, d), "n": (B, d), "h": (B, d), "m": (B, d)}


def slstm_init_state(cfg, B, dtype=F32):
    sh = slstm_state_shape(cfg, B)
    return {k: (jnp.full(v, -1e30, F32) if k == "m" else jnp.zeros(v, F32))
            for k, v in sh.items()}


def _slstm_cell(state, wx_t, r, H):
    """One step. wx_t: (B, 4d) precomputed Wx+b; state dict of (B,d)."""
    B, d4 = wx_t.shape
    d = d4 // 4
    dh = d // H
    h_prev = state["h"].reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", h_prev, r.astype(F32)).reshape(B, 4 * d)
    g = wx_t + rec
    i_raw, f_raw, z_raw, o_raw = jnp.split(g, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    ip = jnp.exp(i_raw - m_new)
    fp = jnp.exp(log_f + state["m"] - m_new)
    c = fp * state["c"] + ip * jnp.tanh(z_raw)
    n = fp * state["n"] + ip
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(p, x, cfg, state=None, decode=False):
    B, S, d = x.shape
    H = cfg.num_heads
    xn = apply_norm(p["norm"], x, cfg.norm)
    wx = (xn @ p["w"]).astype(F32) + p["b"].astype(F32)        # (B,S,4d)
    if state is None:
        state = slstm_init_state(cfg, B)
    if decode:
        assert S == 1
        new_state = _slstm_cell(state, wx[:, 0], p["r"], H)
        h = new_state["h"][:, None]
    else:
        def step(st, wx_t):
            st2 = _slstm_cell(st, wx_t, p["r"], H)
            return st2, st2["h"]
        new_state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
        h = hs.swapaxes(0, 1)                                  # (B,S,d)
    y = x + h.astype(x.dtype)
    # post up-projection gated FFN
    yn = apply_norm(p["ffn_norm"], y, cfg.norm)
    ff = jax.nn.gelu(yn @ p["ff_gate"]) * (yn @ p["ff_up"])
    return y + ff @ p["ff_down"], new_state
