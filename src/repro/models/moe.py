"""Mixture-of-Experts layer with expert parallelism and CUCo-style overlap.

Three execution modes:

* ``local``      — no mesh (smoke tests): full experts on one device.
* ``replicated`` — activations TP-replicated; experts sharded over the model
  axis; each TP rank dispatches its local tokens to its expert shard and the
  partial outputs are psum'd over model (communication cost identical to the
  dense-MLP TP all-reduce it replaces). Used by granite-moe.
* ``alltoall``   — paper-faithful EP: experts sharded over the (pod, data)
  axes; tokens are dispatched to expert owners via ``jax.lax.all_to_all``;
  feed-forward is TP-sharded over model. Supports the CUCo-discovered
  **self/remote split**: the self-chunk expert GEMM has no data dependency on
  the dispatch all-to-all, so XLA's latency-hiding scheduler runs dispatch
  concurrently with local compute (the paper's two-stream overlap, §4.3).
  Optional int8 dispatch quantization (the paper's FP8-quantize phase,
  adapted) halves dispatch wire bytes. Used by llama4-maverick.

Capacity-based static shapes throughout (GShard-style token dropping).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init
from repro.compat import axis_size, shard_map

F32 = jnp.float32


def moe_init(key, cfg, dtype):
    E, d, f = cfg.num_experts_padded, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, F32).astype(F32),   # router kept f32
        "wg": (jax.random.normal(ks[1], (E, d, f), F32) / math.sqrt(d)).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, f), F32) / math.sqrt(d)).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, f, d), F32) / math.sqrt(f)).astype(dtype),
    }
    if cfg.shared_expert:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d, cfg.moe_d_ff, "swiglu", dtype)
    return p


def moe_param_specs(cfg, rules):
    """PartitionSpecs for the MoE params (matching moe_init structure)."""
    e_ax = rules.axes("experts_data" if cfg.ep_mode == "alltoall" else "experts_model")
    f_ax = rules.axes("ff") if cfg.ep_mode == "alltoall" else None
    specs = {
        "router": P(None, None),
        "wg": P(e_ax, None, f_ax),
        "wu": P(e_ax, None, f_ax),
        "wd": P(e_ax, f_ax, None),
    }
    if cfg.shared_expert:
        specs["shared"] = {"gate": P(None, rules.axes("ff")),
                           "up": P(None, rules.axes("ff")),
                           "down": P(rules.axes("ff"), None)}
    return specs


# ------------------------------------------------------------------- routing

def _route(x2, router_w, cfg):
    """x2: (T, d) -> gates (T, k) f32, idx (T, k) int32."""
    logits = x2.astype(F32) @ router_w.astype(F32)                 # (T, E_pad)
    E_pad = logits.shape[-1]
    if E_pad > cfg.num_experts:                                    # mask pad experts
        valid = jnp.arange(E_pad) < cfg.num_experts
        logits = jnp.where(valid[None, :], logits, -jnp.inf)
    gates, idx = jax.lax.top_k(logits, cfg.experts_per_token)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx.astype(jnp.int32)


def _dispatch_indices(idx, E_pad, C):
    """idx: (T, k). Returns flat (T*k,) expert ids, within-expert slot, keep."""
    flat_e = idx.reshape(-1)
    oh = jax.nn.one_hot(flat_e, E_pad, dtype=jnp.int32)            # (Tk, E)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)      # slot in expert
    keep = pos < C
    return flat_e, pos, keep


def _expert_ffn(buf, wg, wu, wd):
    """buf: (E, C, d) x w*: (E, d, f)/(E, f, d) -> (E, C, d). SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _capacity(T, k, E, cap_factor):
    return max(1, int(math.ceil(cap_factor * T * k / E)))


def _quantize_i8(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(F32) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ----------------------------------------------------------- execution paths

def _local_moe(x, p, cfg):
    """Single-device path (also the oracle for the sharded paths)."""
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    k, E_pad = cfg.experts_per_token, cfg.num_experts_padded
    C = _capacity(T, k, cfg.num_experts, cfg.capacity_factor)
    gates, idx = _route(x2, p["router"], cfg)
    flat_e, pos, keep = _dispatch_indices(idx, E_pad, C)
    tok = jnp.arange(T * k) // k
    slot = jnp.where(keep, flat_e * C + pos, E_pad * C)
    buf = jnp.zeros((E_pad * C + 1, d), x.dtype).at[slot].add(
        x2[tok] * keep[:, None].astype(x.dtype))
    h = _expert_ffn(buf[:-1].reshape(E_pad, C, d), p["wg"], p["wu"], p["wd"])
    contrib = h.reshape(E_pad * C, d)[jnp.minimum(slot, E_pad * C - 1)]
    contrib = contrib * (gates.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if cfg.shared_expert:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(p["shared"], x2, "swiglu")
    return y.reshape(B, S, d)


def _replicated_body(x, router, wg, wu, wd, shared, *, cfg, tp_axis):
    """Per-device body: experts sharded over `tp_axis`; psum combine."""
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    k, E_pad = cfg.experts_per_token, cfg.num_experts_padded
    E_l = wg.shape[0]
    n_shards = E_pad // E_l
    C = _capacity(T, k, cfg.num_experts, cfg.capacity_factor)
    gates, idx = _route(x2, router, cfg)
    flat_e, pos, keep = _dispatch_indices(idx, E_pad, C)
    m = jax.lax.axis_index(tp_axis) % n_shards if tp_axis else 0
    local_e = flat_e - m * E_l
    mine = (local_e >= 0) & (local_e < E_l) & keep
    tok = jnp.arange(T * k) // k
    slot = jnp.where(mine, local_e * C + pos, E_l * C)
    buf = jnp.zeros((E_l * C + 1, d), x.dtype).at[slot].add(
        x2[tok] * mine[:, None].astype(x.dtype))
    h = _expert_ffn(buf[:-1].reshape(E_l, C, d), wg, wu, wd)
    contrib = h.reshape(E_l * C, d)[jnp.minimum(slot, E_l * C - 1)]
    contrib = contrib * (gates.reshape(-1, 1) * mine[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if cfg.shared_expert:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(shared, x2, "swiglu")   # ff-sharded partial: in psum
    if tp_axis:
        y = jax.lax.psum(y, tp_axis)
    return y.reshape(B, S, d)


def _alltoall_body(x, router, wg, wu, wd, shared, *, cfg, dp_axes, tp_axes,
                   overlap, quantize):
    """Paper-faithful EP: dispatch A2A -> expert FFN (ff TP) -> combine A2A.

    With ``overlap=True`` the self-chunk FFN is computed from the *local* send
    buffer (no dependency on the dispatch all-to-all) — the CUCo two-stream
    split. The remote chunk is zero-masked so its slots contribute nothing
    twice. Costs 1/ep extra FLOPs; hides dispatch latency behind self-compute.
    """
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    k, E_pad = cfg.experts_per_token, cfg.num_experts_padded
    ep = 1
    for a in dp_axes:
        ep *= axis_size(a)
    E_l = E_pad // ep
    C = _capacity(T, k, cfg.num_experts, cfg.capacity_factor)
    gates, idx = _route(x2, router, cfg)
    flat_e, pos, keep = _dispatch_indices(idx, E_pad, C)
    tok = jnp.arange(T * k) // k
    slot = jnp.where(keep, flat_e * C + pos, E_pad * C)
    buf = jnp.zeros((E_pad * C + 1, d), x.dtype).at[slot].add(
        x2[tok] * keep[:, None].astype(x.dtype))
    buf = buf[:-1].reshape(ep, E_l, C, d)                    # dst-major layout
    r = jax.lax.axis_index(dp_axes)

    def ffn(chunk):                                          # (..., E_l, C, d)
        c = chunk.reshape(-1, E_l, C, d)
        cg = c.transpose(1, 0, 2, 3).reshape(E_l, -1, d)     # group tokens by expert
        h = _expert_ffn(cg, wg, wu, wd)                      # ff TP partial sums
        if tp_axes:
            h = jax.lax.psum(h, tp_axes)
        h = h.reshape(E_l, -1, C, d).transpose(1, 0, 2, 3)
        return h.reshape(chunk.shape)

    if overlap:
        self_chunk = buf[r]                                  # (E_l, C, d) local
        h_self = ffn(self_chunk)                             # independent of A2A
        send = buf
        if quantize:
            q, sc = _quantize_i8(send)
            q = jax.lax.all_to_all(q, dp_axes, 0, 0, tiled=True)
            sc = jax.lax.all_to_all(sc, dp_axes, 0, 0, tiled=True)
            recv = (q.astype(F32) * sc).astype(x.dtype)
        else:
            recv = jax.lax.all_to_all(send, dp_axes, 0, 0, tiled=True)
        src = jnp.arange(ep)
        recv_remote = jnp.where((src != r)[:, None, None, None], recv, 0)
        h_remote = ffn(recv_remote)                          # self rows are 0
        h = h_remote.at[r].add(h_self)
    else:
        if quantize:
            q, sc = _quantize_i8(buf)
            q = jax.lax.all_to_all(q, dp_axes, 0, 0, tiled=True)
            sc = jax.lax.all_to_all(sc, dp_axes, 0, 0, tiled=True)
            recv = (q.astype(F32) * sc).astype(x.dtype)
        else:
            recv = jax.lax.all_to_all(buf, dp_axes, 0, 0, tiled=True)
        h = ffn(recv)
    back = jax.lax.all_to_all(h, dp_axes, 0, 0, tiled=True)  # combine
    y_slots = back.reshape(E_pad * C, d)
    contrib = y_slots[jnp.minimum(slot, E_pad * C - 1)]
    contrib = contrib * (gates.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if cfg.shared_expert:
        from repro.models.layers import mlp_apply
        sh = mlp_apply(shared, x2, "swiglu")                 # also A2A-independent
        if tp_axes:
            sh = jax.lax.psum(sh, tp_axes)                   # ff-sharded partial
        y = y + sh
    return y.reshape(B, S, d)


def _pallas_body(x, router, wg, wu, wd, shared, *, cfg, dp_axis, overlap,
                 quantize, interpret, probe):
    """The PALLAS_RDMA branch (the serving hot path): routing/capacity
    layout identical to ``_alltoall_body`` up to the dst-major capacity
    buffer, but dispatch → expert FFN → combine runs as ONE fused
    device-initiated kernel (``kernels/moe_dispatch``, FLUX knobs:
    tile_fused + COUNTER). With ``overlap`` and a shared expert, the
    shared-expert FFN is the kernel's second stream — issued against the
    open dispatch send window (the TokenWeave two-stream overlap,
    executably). Eligibility is gated by :func:`pallas_moe_eligible`;
    the capacity-slot layout makes the kernel's output slab bit-match
    the XLA path's ``y_slots``, so combine/gather code is shared."""
    from repro.core.schedule import make_schedule
    from repro.kernels.moe_dispatch import moe_dispatch_combine_sharded
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    k, E_pad = cfg.experts_per_token, cfg.num_experts_padded
    ep = axis_size(dp_axis)
    C = _capacity(T, k, cfg.num_experts, cfg.capacity_factor)
    gates, idx = _route(x2, router, cfg)
    flat_e, pos, keep = _dispatch_indices(idx, E_pad, C)
    tok = jnp.arange(T * k) // k
    slot = jnp.where(keep, flat_e * C + pos, E_pad * C)
    buf = jnp.zeros((E_pad * C + 1, d), x.dtype).at[slot].add(
        x2[tok] * keep[:, None].astype(x.dtype))
    # (ep*C, d): contiguous per-expert capacity blocks — exactly the
    # sorted-block layout the dispatch kernel's static counts contract
    # wants (uniform counts == C, so the schedule has no dummy blocks)
    xk = buf[:-1]
    w1 = jnp.concatenate([wg[0], wu[0]], axis=-1)        # (d, 2f) swiglu
    w2 = wd[0]                                           # (f, d)
    sched = make_schedule([C] * ep, block_tokens=min(64, C), tight=True)
    shared_op = None
    if overlap and shared is not None:
        s1 = jnp.concatenate([shared["gate"], shared["up"]], axis=-1)
        shared_op = (x2.astype(F32), s1.astype(F32),
                     shared["down"].astype(F32))
    out = moe_dispatch_combine_sharded(
        xk.astype(F32), w1.astype(F32), w2.astype(F32), axis=dp_axis,
        sched=sched, tile_fused=True, pipelined=True, barrier=False,
        contexts=2, wire_i8=quantize, shared=shared_op,
        interpret=interpret, probe=probe)
    y_slots, ys = out if shared_op is not None else (out, None)
    contrib = y_slots.astype(x.dtype)[jnp.minimum(slot, E_pad * C - 1)]
    contrib = contrib * (gates.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if shared is not None:
        if ys is not None:
            y = y + ys.astype(x.dtype)                   # second stream
        else:
            from repro.models.layers import mlp_apply
            y = y + mlp_apply(shared, x2, "swiglu")
    return y.reshape(B, S, d)


def pallas_moe_eligible(cfg, rules, B):
    """Can this (config, sharding, batch) route through the fused
    dispatch kernel? Requirements mirror the kernel contract: alltoall
    EP over exactly one data axis (the kernel's named-axis mesh), no ff
    TP (expert weights whole per rank), batch shardable, and exactly one
    expert per rank (``E_pad == ep`` — the DeepSeek-V3-style serving
    deployment). Ineligible shapes silently take the XLA paths."""
    if rules is None or rules.mesh is None or cfg.ep_mode != "alltoall":
        return False
    dp = rules.dp_size()
    if not (dp and B % dp == 0 and B >= dp):
        return False
    if len(rules.dp_axes) != 1 or rules.tp_axes:
        return False
    return cfg.num_experts_padded == dp


def _gathered_body(x, router, wg, wu, wd, shared, *, cfg, dp_axes, tp_axes):
    """Decode path when batch is too small to shard (e.g. long_500k, B=1):
    tokens replicated over DP; experts sharded over DP; ff over TP; psum-all.
    """
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    k, E_pad = cfg.experts_per_token, cfg.num_experts_padded
    E_l = wg.shape[0]
    ep = E_pad // E_l
    C = _capacity(T, k, cfg.num_experts, cfg.capacity_factor)
    gates, idx = _route(x2, router, cfg)
    flat_e, pos, keep = _dispatch_indices(idx, E_pad, C)
    r = jax.lax.axis_index(dp_axes) % ep
    local_e = flat_e - r * E_l
    mine = (local_e >= 0) & (local_e < E_l) & keep
    tok = jnp.arange(T * k) // k
    slot = jnp.where(mine, local_e * C + pos, E_l * C)
    buf = jnp.zeros((E_l * C + 1, d), x.dtype).at[slot].add(
        x2[tok] * mine[:, None].astype(x.dtype))
    h = _expert_ffn(buf[:-1].reshape(E_l, C, d), wg, wu, wd)
    if tp_axes:
        h = jax.lax.psum(h, tp_axes)
    contrib = h.reshape(E_l * C, d)[jnp.minimum(slot, E_l * C - 1)]
    contrib = contrib * (gates.reshape(-1, 1) * mine[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    y = jax.lax.psum(y, dp_axes)
    if cfg.shared_expert:
        from repro.models.layers import mlp_apply
        sh = mlp_apply(shared, x2, "swiglu")
        if tp_axes:
            sh = jax.lax.psum(sh, tp_axes)                   # ff-sharded partial
        y = y + sh
    return y.reshape(B, S, d)


# ---------------------------------------------------------------- public API

def moe_apply(params, x, cfg, rules, *, overlap=False, quantize=False,
              backend="xla", interpret=None, probe=None):
    """Apply the MoE block. x: (B, S, d) global.

    ``backend="pallas"`` routes the dispatch→FFN→combine chain through the
    fused ``kernels/moe_dispatch`` kernel (FLUX point) when
    :func:`pallas_moe_eligible` holds — with ``overlap`` the shared-expert
    FFN becomes the kernel's second stream (the TokenWeave point). The
    kernel's ``interpret``/``probe`` plumb through for tests."""
    if rules is None or rules.mesh is None:
        return _local_moe(x, params, cfg)

    mesh = rules.mesh
    dp_axes = rules.dp_axes
    tp_axes = rules.tp_axes
    B = x.shape[0]
    dp = rules.dp_size()
    pspecs = moe_param_specs(cfg, rules)
    shared = params.get("shared")
    shared_spec = pspecs.get("shared")
    b_ok = dp and B % dp == 0 and B >= dp
    x_spec = P(rules.axes("batch") if b_ok else None, None, None)

    if backend == "pallas" and pallas_moe_eligible(cfg, rules, B):
        body = partial(_pallas_body, cfg=cfg, dp_axis=dp_axes[0],
                       overlap=overlap, quantize=quantize,
                       interpret=interpret, probe=probe)
        in_specs = (x_spec, pspecs["router"], pspecs["wg"], pspecs["wu"],
                    pspecs["wd"], shared_spec)
    elif cfg.ep_mode == "alltoall" and b_ok:
        body = partial(_alltoall_body, cfg=cfg, dp_axes=dp_axes, tp_axes=tp_axes,
                       overlap=overlap, quantize=quantize)
        in_specs = (x_spec, pspecs["router"], pspecs["wg"], pspecs["wu"],
                    pspecs["wd"], shared_spec)
    elif cfg.ep_mode == "alltoall":
        body = partial(_gathered_body, cfg=cfg, dp_axes=dp_axes, tp_axes=tp_axes)
        in_specs = (P(None, None, None), pspecs["router"], pspecs["wg"],
                    pspecs["wu"], pspecs["wd"], shared_spec)
        x_spec = P(None, None, None)
    else:
        body = partial(_replicated_body, cfg=cfg, tp_axis=tp_axes)
        in_specs = (x_spec, pspecs["router"], pspecs["wg"], pspecs["wu"],
                    pspecs["wd"], shared_spec)

    if shared is None:
        in_specs = in_specs[:-1] + (None,)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=x_spec,
                       check_vma=False)
    return fn(x, params["router"], params["wg"], params["wu"], params["wd"], shared)
