"""Model assembly: init / param specs / train loss / prefill / decode.

Layers are stacked over *repeat units* (the lcm of the block pattern and the
MoE interleave) and applied with ``jax.lax.scan`` so the lowered HLO stays
compact for deep models. Step-level schedule knobs (remat, MoE overlap,
flash block sizes …) live in ``StepOptions`` — the surface the CUCo search
(repro.core) optimizes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_norm, dense_init, norm_init
from repro.models.moe import moe_param_specs
from repro.models.rglru import (rglru_apply, rglru_init, rglru_init_state,
                                rglru_state_shape)
from repro.models.transformer import (attn_block_apply, attn_block_init,
                                      cache_size)
from repro.models.xlstm import (mlstm_apply, mlstm_init, mlstm_init_state,
                                mlstm_state_shape, slstm_apply, slstm_init,
                                slstm_init_state, slstm_state_shape)
from repro.compat import shard_map

F32 = jnp.float32
MAX_LEARNED_POS = 32768


@dataclass(frozen=True)
class StepOptions:
    """Schedule knobs searched by the CUCo slow path (repro.core)."""
    remat: bool = True
    moe_overlap: bool = False        # CUCo self/remote split dispatch hiding
    moe_quantize: bool = False       # int8 dispatch (paper's quantize phase)
    moe_backend: str = "xla"         # "pallas": fused dispatch kernel (FLUX)
    kv_block: int = 1024             # lax-flash KV block
    flash_threshold: int = 8192
    scan_layers: bool = True
    loss_chunk: int = 0              # >0: chunked CE loss (seq chunks)
    seq_parallel: bool = False       # prefill: activations sharded over seq
    sp_residuals: bool = False       # train: remat carries sharded over seq


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# =============================================================== param init

def _block_init(key, cfg, slot, dtype):
    kind = cfg.block_kind(slot)
    if kind == "mlstm":
        return mlstm_init(key, cfg, dtype)
    if kind == "slstm":
        return slstm_init(key, cfg, dtype)
    if kind == "rglru":
        ks = jax.random.split(key, 2)
        from repro.models.layers import mlp_init
        return {"rglru": rglru_init(ks[0], cfg, dtype),
                "mlp_norm": norm_init(cfg.d_model, cfg.norm, dtype),
                "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)}
    return attn_block_init(key, cfg, slot, dtype, cross=cfg.is_encoder_decoder)


def init_params(key, cfg):
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    Vp, d = cfg.vocab_padded, cfg.d_model
    params = {"embed": dense_init(keys[0], Vp, d, dtype, scale=0.02).reshape(Vp, d)}
    if cfg.learned_pos:
        params["pos"] = dense_init(keys[1], MAX_LEARNED_POS, d, dtype, scale=0.02)
    unit, R = cfg.repeat_unit, cfg.num_repeats

    def stack_slot(slot):
        ks = jax.random.split(jax.random.fold_in(keys[2], slot), R)
        leaves = [_block_init(k, cfg, slot, dtype) for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    params["blocks"] = {f"s{i}": stack_slot(i) for i in range(unit)}
    params["final_norm"] = norm_init(d, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[3], d, Vp, dtype)
    if cfg.is_encoder_decoder:
        ks = jax.random.split(keys[4], cfg.enc_layers)
        enc_leaves = [attn_block_init(k, cfg, 10**6, dtype, cross=False)
                      for k in ks]                      # 10**6: never MoE
        params["enc"] = {
            "pos": dense_init(keys[5], cfg.enc_seq, d, dtype, scale=0.02),
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_leaves),
            "final_norm": norm_init(d, cfg.norm, dtype),
        }
    return params


# ============================================================== param specs

def _attn_specs(cfg, rules, cross):
    sp = {
        "norm": {"w": P(None)} if cfg.norm == "rmsnorm" else {"w": P(None), "b": P(None)},
        "attn": {"q": P(None, rules.axes("heads")),
                 "k": P(None, rules.axes("kv_heads")),
                 "v": P(None, rules.axes("kv_heads")),
                 "o": P(rules.axes("heads"), None)},
        "mlp_norm": {"w": P(None)} if cfg.norm == "rmsnorm" else {"w": P(None), "b": P(None)},
    }
    if cross:
        sp["cross_norm"] = sp["norm"]
        sp["cross"] = sp["attn"]
    return sp


def _norm_spec(cfg):
    return {"w": P(None)} if cfg.norm == "rmsnorm" else {"w": P(None), "b": P(None)}


def _block_specs(cfg, slot, rules):
    kind = cfg.block_kind(slot)
    ff = rules.axes("ff")
    if kind == "mlstm":
        return {"norm": _norm_spec(cfg), "up": P(None, ff), "q": P(None, ff),
                "k": P(None, ff), "v": P(None, ff), "wi": P(None, None),
                "wf": P(None, None), "bf": P(None), "bi": P(None),
                "hnorm": {"w": P(None)}, "down": P(ff, None)}
    if kind == "slstm":
        return {"norm": _norm_spec(cfg), "w": P(None, ff), "r": P(None, None, None),
                "b": P(None), "ffn_norm": _norm_spec(cfg),
                "ff_gate": P(None, ff), "ff_up": P(None, ff), "ff_down": P(ff, None)}
    if kind == "rglru":
        return {"rglru": {"norm": _norm_spec(cfg), "in_a": P(None, ff),
                          "in_b": P(None, ff), "conv_w": P(None, ff),
                          "conv_b": P(ff), "wr": P(None, ff), "wi": P(None, ff),
                          "lam": P(ff), "out": P(ff, None)},
                "mlp_norm": _norm_spec(cfg),
                "mlp": _mlp_specs(cfg, rules)}
    sp = _attn_specs(cfg, rules, cfg.is_encoder_decoder)
    if cfg.layer_is_moe(slot):
        sp["moe"] = moe_param_specs(cfg, rules)
    else:
        sp["mlp"] = _mlp_specs(cfg, rules)
    return sp


def _mlp_specs(cfg, rules):
    ff = rules.axes("ff")
    if cfg.act == "swiglu":
        return {"gate": P(None, ff), "up": P(None, ff), "down": P(ff, None)}
    return {"up": P(None, ff), "down": P(ff, None)}


def _prepend(spec, extra=None):
    """Add the leading stacking dim (repeats) to every leaf spec."""
    return jax.tree.map(lambda s: P(extra, *s), spec,
                        is_leaf=lambda s: isinstance(s, P))


def param_specs(cfg, rules):
    """Pytree of PartitionSpec matching init_params(cfg). Strict-divisible."""
    vocab = rules.axes("vocab")
    specs = {"embed": P(vocab, None)}
    if cfg.learned_pos:
        specs["pos"] = P(None, None)
    specs["blocks"] = {f"s{i}": _prepend(_block_specs(cfg, i, rules))
                       for i in range(cfg.repeat_unit)}
    specs["final_norm"] = _norm_spec(cfg)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, vocab)
    if cfg.is_encoder_decoder:
        specs["enc"] = {
            "pos": P(None, None),
            "blocks": _prepend(_attn_specs(cfg, rules, cross=False)
                               | {"mlp": _mlp_specs(cfg, rules)}),
            "final_norm": _norm_spec(cfg),
        }
    return specs


# ============================================================ embed / logits

def embed_lookup(embed, ids, rules):
    """Vocab-parallel embedding lookup (Megatron-style masked psum)."""
    if rules is None or rules.mesh is None or rules.axes("vocab") is None:
        return embed[ids]
    tp = rules.axes("vocab")
    Vp = embed.shape[0]
    tp_size = rules.size("vocab")
    if Vp % tp_size != 0:
        return embed[ids]
    B = ids.shape[0]
    bspec = rules.axes("batch") if (rules.dp_size() and B % rules.dp_size() == 0
                                    and B >= rules.dp_size()) else None

    def body(emb_l, ids_l):
        Vl = emb_l.shape[0]
        lo = jax.lax.axis_index(tp) * Vl
        loc = ids_l - lo
        ok = (loc >= 0) & (loc < Vl)
        out = emb_l[jnp.clip(loc, 0, Vl - 1)] * ok[..., None].astype(emb_l.dtype)
        return jax.lax.psum(out, tp)

    return shard_map(
        body, mesh=rules.mesh,
        in_specs=(P(tp, None), P(bspec, None)),
        out_specs=P(bspec, None, None), check_vma=False,
    )(embed, ids)


def lm_logits(params, x, cfg, rules):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w.astype(x.dtype)).astype(F32)
    Vp = logits.shape[-1]
    if Vp > cfg.vocab_size:
        valid = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    if rules is not None:
        logits = rules.shard(logits, "batch", None, "vocab")
    return logits


# ================================================================== caches

def init_cache(cfg, B, seq_len, dtype=None):
    """Decode cache pytree (concrete zeros). Structure mirrors cache_specs."""
    dtype = dtype or _dtype(cfg)
    unit, R = cfg.repeat_unit, cfg.num_repeats
    Hkv, hd = cfg.num_kv_heads, cfg.hd
    out = {}
    for i in range(unit):
        kind = cfg.block_kind(i)
        if kind == "mlstm":
            st = mlstm_init_state(cfg, B)
            out[f"s{i}"] = jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape).copy(), st)
        elif kind == "slstm":
            st = slstm_init_state(cfg, B)
            out[f"s{i}"] = jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape).copy(), st)
        elif kind == "rglru":
            st = rglru_init_state(cfg, B, dtype)
            out[f"s{i}"] = jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape).copy(), st)
        else:
            Sc = cache_size(cfg, kind, seq_len)
            c = {"k": jnp.zeros((R, B, Sc, Hkv, hd), dtype),
                 "v": jnp.zeros((R, B, Sc, Hkv, hd), dtype),
                 "kpos": jnp.full((R, Sc), -10**9, jnp.int32)}
            if cfg.is_encoder_decoder:
                c["ck"] = jnp.zeros((R, B, cfg.enc_seq, Hkv, hd), dtype)
                c["cv"] = jnp.zeros((R, B, cfg.enc_seq, Hkv, hd), dtype)
            out[f"s{i}"] = c
    return out


def cache_specs(cfg, B, seq_len, rules):
    """ShapeDtypeStruct + PartitionSpec trees for the decode cache."""
    dtype = _dtype(cfg)
    unit, R = cfg.repeat_unit, cfg.num_repeats
    Hkv, hd = cfg.num_kv_heads, cfg.hd
    shapes, specs = {}, {}
    for i in range(unit):
        kind = cfg.block_kind(i)
        if kind in ("mlstm", "slstm", "rglru"):
            sh = (mlstm_state_shape(cfg, B) if kind == "mlstm" else
                  slstm_state_shape(cfg, B) if kind == "slstm" else
                  rglru_state_shape(cfg, B))
            shapes[f"s{i}"] = {k: jax.ShapeDtypeStruct(
                (R,) + v, dtype if (kind == "rglru" and k == "conv") else F32)
                for k, v in sh.items()}
            specs[f"s{i}"] = {k: rules.param_spec((R,) + v, None, "batch",
                                                  *([None] * (len(v) - 1)))
                              for k, v in sh.items()}
        else:
            Sc = cache_size(cfg, kind, seq_len)
            kv_shape = (R, B, Sc, Hkv, hd)
            shapes[f"s{i}"] = {
                "k": jax.ShapeDtypeStruct(kv_shape, dtype),
                "v": jax.ShapeDtypeStruct(kv_shape, dtype),
                "kpos": jax.ShapeDtypeStruct((R, Sc), jnp.int32)}
            kv_spec = rules.param_spec(kv_shape, None, "batch", "seq_kv", None, None)
            specs[f"s{i}"] = {"k": kv_spec, "v": kv_spec, "kpos": P(None, None)}
            if cfg.is_encoder_decoder:
                csh = (R, B, cfg.enc_seq, Hkv, hd)
                shapes[f"s{i}"]["ck"] = jax.ShapeDtypeStruct(csh, dtype)
                shapes[f"s{i}"]["cv"] = jax.ShapeDtypeStruct(csh, dtype)
                cs = rules.param_spec(csh, None, "batch", None, None, None)
                specs[f"s{i}"]["ck"] = cs
                specs[f"s{i}"]["cv"] = cs
    return shapes, specs


# ================================================================ forward

def _apply_block(p, x, cfg, slot, rules, positions, *, causal, cache, pos,
                 enc_out, opts):
    kind = cfg.block_kind(slot)
    if kind == "mlstm":
        return mlstm_apply(p, x, cfg, state=cache, decode=pos is not None)
    if kind == "slstm":
        return slstm_apply(p, x, cfg, state=cache, decode=pos is not None)
    if kind == "rglru":
        x, st = rglru_apply(p["rglru"], x, cfg, state=cache, decode=pos is not None)
        from repro.models.layers import mlp_apply
        xn = apply_norm(p["mlp_norm"], x, cfg.norm)
        x = x + mlp_apply(p["mlp"], xn, cfg.act)
        if rules is not None:
            seq_ax = "seq_act" if (opts and opts.seq_parallel) else None
            x = rules.shard(x, "batch", seq_ax, None)
        return x, st
    return attn_block_apply(p, x, cfg, kind, rules, positions, causal=causal,
                            cache=cache, pos=pos, enc_out=enc_out, opts=opts)


def apply_blocks(params_blocks, x, cfg, rules, positions, *, causal=True,
                 cache=None, pos=None, enc_out=None, opts=None,
                 return_cache=False):
    unit = cfg.repeat_unit
    opts = opts or StepOptions()

    def body(carry, xs):
        h = carry
        slot_params, slot_cache = xs
        new_caches = {}
        for i in range(unit):
            key = f"s{i}"
            c = slot_cache.get(key) if slot_cache else None
            h, nc = _apply_block(slot_params[key], h, cfg, i, rules, positions,
                                 causal=causal, cache=c, pos=pos,
                                 enc_out=enc_out, opts=opts)
            if opts.seq_parallel and rules is not None:
                h = rules.shard(h, "batch", "seq_act", None)
            new_caches[key] = nc
        if opts.sp_residuals and rules is not None:
            # remat saves the scan carry: store it sequence-sharded (SP
            # activation checkpoints — trades an all-gather per layer for
            # a tp-fold smaller residual footprint)
            h = rules.shard(h, "batch", "seq_res", None)
        if not return_cache:
            return h, None
        return h, new_caches

    if opts.remat and pos is None:
        # prevent_cse=False is only safe under scan (XLA would CSE the
        # rematerialized forward away in the unrolled path).
        body = jax.checkpoint(body, prevent_cse=not opts.scan_layers)

    if opts.scan_layers and cfg.num_repeats > 1:
        x, ys = jax.lax.scan(body, x, (params_blocks, cache))
        return x, ys
    # unrolled
    ys = []
    R = cfg.num_repeats
    for r in range(R):
        sl_p = jax.tree.map(lambda a: a[r], params_blocks)
        sl_c = jax.tree.map(lambda a: a[r], cache) if cache is not None else None
        x, y = body(x, (sl_p, sl_c))
        ys.append(y)
    if return_cache and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return x, ys


def encode(params, frames, cfg, rules, opts=None):
    """Whisper encoder over stub frame embeddings (B, enc_seq, d)."""
    x = frames + params["enc"]["pos"][None, :frames.shape[1]].astype(frames.dtype)
    pos = jnp.arange(frames.shape[1])
    opts = opts or StepOptions()

    def body(h, sl):
        h, _ = attn_block_apply(sl, h, cfg, "attn", rules, pos, causal=False,
                                opts=opts)
        return h, None

    if opts.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc"]["blocks"])
    return apply_norm(params["enc"]["final_norm"], x, cfg.norm)


def forward(params, batch, cfg, rules, opts=None, return_cache=False,
            cache=None):
    """Training / prefill forward. batch: {"tokens", ["frames"|"patches"]}."""
    opts = opts or StepOptions()
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, rules).astype(_dtype(cfg))
    if cfg.num_patch_tokens and "patches" in batch:
        Pn = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x[:, Pn:]], axis=1)
    if cfg.learned_pos:
        x = x + params["pos"][:S][None].astype(x.dtype)
    if rules is not None:
        x = rules.shard(x, "batch", None, None)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"].astype(x.dtype), cfg, rules, opts)
    positions = jnp.arange(S)
    x, new_cache = apply_blocks(params["blocks"], x, cfg, rules, positions,
                                causal=True, cache=cache, enc_out=enc_out,
                                opts=opts, return_cache=return_cache)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, new_cache


def _ce_terms(params, x, labels, cfg, rules):
    logits = lm_logits(params, x, cfg, rules)
    mask = (labels >= 0)
    labels_c = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def train_loss(params, batch, cfg, rules, opts=None):
    opts = opts or StepOptions()
    x, _ = forward(params, batch, cfg, rules, opts)
    labels = batch["labels"]
    S = labels.shape[1]
    ck = opts.loss_chunk
    if ck and S % ck == 0 and S > ck:
        # chunked CE: never materialize full (B, S, V) logits
        xs = x.reshape(x.shape[0], S // ck, ck, x.shape[-1]).swapaxes(0, 1)
        ls = labels.reshape(labels.shape[0], S // ck, ck).swapaxes(0, 1)

        def step(carry, blk):
            xb, lb = blk
            n, c = _ce_terms(params, xb, lb, cfg, rules)
            return (carry[0] + n, carry[1] + c), None

        step = jax.checkpoint(step, prevent_cse=False)
        (nll, cnt), _ = jax.lax.scan(step, (jnp.zeros((), F32), jnp.zeros((), F32)),
                                     (xs, ls))
        return nll / jnp.maximum(cnt, 1)
    nll, cnt = _ce_terms(params, x, labels, cfg, rules)
    return nll / jnp.maximum(cnt, 1)


def prefill_step(params, batch, cfg, rules, seq_len=None, opts=None):
    """Prefill: build the decode cache + last-position logits."""
    opts = opts or StepOptions()
    S = batch["tokens"].shape[1]
    B = batch["tokens"].shape[0]
    cache = init_cache(cfg, B, seq_len or S)
    x, new_cache = forward(params, batch, cfg, rules, opts, return_cache=True,
                           cache=cache)
    logits = lm_logits(params, x[:, -1:], cfg, rules)
    return logits, new_cache


def decode_step(params, cache, token, pos, cfg, rules, opts=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32."""
    opts = opts or StepOptions()
    x = embed_lookup(params["embed"], token, rules).astype(_dtype(cfg))
    if cfg.learned_pos:
        x = x + jax.lax.dynamic_slice(params["pos"], (pos % MAX_LEARNED_POS, 0),
                                      (1, cfg.d_model))[None].astype(x.dtype)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    x, new_cache = apply_blocks(params["blocks"], x, cfg, rules, positions,
                                causal=True, cache=cache, pos=pos, opts=opts,
                                return_cache=True)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, x, cfg, rules)
    return logits, new_cache
