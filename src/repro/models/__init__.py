from repro.models.model import (StepOptions, init_params, param_specs,
                                train_loss, prefill_step, decode_step,
                                init_cache, cache_specs, forward)

__all__ = [
    "StepOptions", "init_params", "param_specs", "train_loss",
    "prefill_step", "decode_step", "init_cache", "cache_specs", "forward",
]
