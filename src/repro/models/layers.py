"""Core NN layers shared by all architectures.

Attention supports four kinds (full ``attn``, ``local_attn`` with a sliding
window, ``chunked_attn`` with block-diagonal chunks, and NoPE ``global_attn``)
over a single masked-softmax core with two execution paths:

* dense einsum (short sequences),
* memory-efficient lax.scan over KV blocks with a running-max/denominator
  (pure-JAX flash attention) for long sequences — required so prefill_32k fits.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32

# ---------------------------------------------------------------- init utils

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), F32) * scale).astype(dtype)


def norm_init(d, norm_kind, dtype):
    if norm_kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


# --------------------------------------------------------------------- norms

def apply_norm(params, x, norm_kind, eps=1e-6):
    xf = x.astype(F32)
    if norm_kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * params["w"].astype(F32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["w"].astype(F32) + params["b"].astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE

def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=F32) / half)
    ang = positions[..., None].astype(F32) * freqs                # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

NEG_INF = -1e30


def attn_mask(qpos, kpos, kind, window=0, chunk=0, causal=True):
    """Boolean mask (Sq, Skv): True = attend."""
    q = qpos[:, None]
    k = kpos[None, :]
    m = (q >= k) if causal else jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if kind == "local_attn":
        m = m & (q - k < window)
    elif kind == "chunked_attn":
        m = m & (q // chunk == k // chunk)
    return m


def _dense_attention(q, k, v, qpos, kpos, kind, window, chunk, causal, scale):
    """Grouped GQA attention: q (B,Sq,Hkv,G,hd), k/v (B,Skv,Hkv,hd) — the KV
    heads are never materialized repeated (a 48x cache-traffic saving for
    MQA decode; see EXPERIMENTS.md §Perf iteration 1)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(F32) * scale
    m = attn_mask(qpos, kpos, kind, window, chunk, causal)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _flash_attention(q, k, v, qpos, kpos, kind, window, chunk, causal, scale,
                     kv_block=1024, q_block=1024):
    """Memory-efficient grouped attention: scan over Q blocks x KV blocks
    with a running softmax. q: (B,Sq,Hkv,G,hd); k/v: (B,Skv,Hkv,hd).
    Memory is O(q_block * kv_block) per step."""
    B, Sq, Hkv, G, hd = q.shape
    if Sq > q_block and Sq % q_block == 0:
        nq = Sq // q_block
        qs = q.reshape(B, nq, q_block, Hkv, G, hd).swapaxes(0, 1)
        qp = qpos.reshape(nq, q_block)

        def qstep(_, blk):
            qb, qpb = blk
            o = _flash_attention(qb, k, v, qpb, kpos, kind, window, chunk,
                                 causal, scale, kv_block, q_block)
            return None, o

        _, outs = jax.lax.scan(qstep, None, (qs, qp))
        return outs.swapaxes(0, 1).reshape(B, Sq, Hkv, G, hd)
    Skv = k.shape[1]
    nb = -(-Skv // kv_block)
    pad = nb * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-10**9)  # masked out
    k = k.reshape(B, nb, kv_block, Hkv, hd)
    v = v.reshape(B, nb, kv_block, Hkv, hd)
    kpos = kpos.reshape(nb, kv_block)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kb, vb, kpb = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kb).astype(F32) * scale
        mask = attn_mask(qpos, kpb, kind, window, chunk, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb).astype(F32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, F32)
    l0 = jnp.zeros((B, Hkv, G, Sq), F32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), F32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (k.swapaxes(0, 1), v.swapaxes(0, 1), kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,Hkv,G,hd)


def attention(q, k, v, qpos, kpos, kind="attn", window=0, chunk=0, causal=True,
              flash_threshold=8192, kv_block=1024):
    """GQA attention. q: (B,Sq,Hq,hd), k/v: (B,Skv,Hkv,hd). The query heads
    are grouped as (Hkv, G) so KV is never repeated in memory."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scale = 1.0 / math.sqrt(hd)
    if k.shape[1] > flash_threshold and Sq > 1:
        out = _flash_attention(qg, k, v, qpos, kpos, kind, window, chunk,
                               causal, scale, kv_block)
    else:
        out = _dense_attention(qg, k, v, qpos, kpos, kind, window, chunk,
                               causal, scale)
    return out.reshape(B, Sq, Hq, hd)


# ----------------------------------------------------------------------- MLP

def mlp_init(key, d, d_ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {"gate": dense_init(k1, d, d_ff, dtype),
                "up": dense_init(k2, d, d_ff, dtype),
                "down": dense_init(k3, d_ff, d, dtype)}
    return {"up": dense_init(k1, d, d_ff, dtype),
            "down": dense_init(k2, d_ff, d, dtype)}


def mlp_apply(params, x, act):
    if act == "swiglu":
        h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    else:
        h = jax.nn.gelu(x @ params["up"])
    return h @ params["down"]


# ------------------------------------------------------------ attention blok

def attn_init(key, cfg, dtype, cross=False):
    """Weights stored flattened (d, H*hd) so the sharded dim divides the mesh."""
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "q": dense_init(ks[0], d, hq * hd, dtype),
        "k": dense_init(ks[1], d, hkv * hd, dtype),
        "v": dense_init(ks[2], d, hkv * hd, dtype),
        "o": dense_init(ks[3], hq * hd, d, dtype, scale=1.0 / math.sqrt(hq * hd)),
    }


def qkv(params, x, cfg, positions, use_rope, rules=None):
    """Project to (B,S,H,hd) q/k/v, applying RoPE if requested."""
    B, S, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ params["q"]).reshape(B, S, hq, hd)
    k = (x @ params["k"]).reshape(B, S, hkv, hd)
    v = (x @ params["v"]).reshape(B, S, hkv, hd)
    if rules is not None:
        q = rules.shard(q, "batch", None, "heads", None)
        k = rules.shard(k, "batch", None, "kv_heads", None)
        v = rules.shard(v, "batch", None, "kv_heads", None)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v
