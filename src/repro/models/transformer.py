"""Transformer blocks (attention kinds + MLP/MoE) with decode caches.

Decode caches for local/chunked attention are ring buffers of size
window/chunk; a ``kpos`` array records the absolute position held in each
slot (stale slots are masked out by the attention mask automatically).
Decode is batch-uniform (all rows at the same position).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (attn_init, attention, apply_norm, mlp_apply,
                                 mlp_init, norm_init, qkv)
from repro.models.moe import moe_apply, moe_init

F32 = jnp.float32


def attn_block_init(key, cfg, layer_idx, dtype, cross=False):
    ks = jax.random.split(key, 6)
    p = {
        "norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn_init(ks[0], cfg, dtype),
        "mlp_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cross:
        p["cross_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["cross"] = attn_init(ks[1], cfg, dtype)
    if cfg.layer_is_moe(layer_idx):
        p["moe"] = moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def cache_size(cfg, kind, seq_len):
    if kind == "local_attn":
        return min(cfg.window, seq_len)
    if kind == "chunked_attn":
        return min(cfg.chunk, seq_len)
    return seq_len


def _use_rope(cfg, kind):
    if not cfg.use_rope:
        return False
    return kind != "global_attn"          # NoPE layers (llama4 iRoPE)


def attn_block_apply(p, x, cfg, kind, rules, positions, *, causal=True,
                     cache=None, pos=None, enc_out=None, opts=None):
    """Returns (x, new_cache). cache: {"k","v","kpos"} or None (train)."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.hd
    xn = apply_norm(p["norm"], x, cfg.norm)
    q, k, v = qkv(p["attn"], xn, cfg, positions, _use_rope(cfg, kind), rules)
    new_cache = None
    kv_block = opts.kv_block if opts else 1024
    fth = opts.flash_threshold if opts else 8192
    if cache is not None and pos is not None:        # decode step
        Sc = cache["k"].shape[1]
        slot = pos % Sc
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        kpos = jax.lax.dynamic_update_slice(cache["kpos"], pos[None].astype(jnp.int32),
                                            (slot,))
        if rules is not None:
            kc = rules.shard(kc, "batch", "seq_kv", None, None)
            vc = rules.shard(vc, "batch", "seq_kv", None, None)
        o = attention(q, kc, vc, pos[None], kpos, kind, cfg.window, cfg.chunk,
                      causal=True, flash_threshold=fth, kv_block=kv_block)
        new_cache = {"k": kc, "v": vc, "kpos": kpos}
    else:
        o = attention(q, k, v, positions, positions, kind, cfg.window, cfg.chunk,
                      causal=causal, flash_threshold=fth, kv_block=kv_block)
        if cache is not None:                        # prefill: fill the cache
            Sc = cache["k"].shape[1]
            if Sc == S:
                kpos = positions.astype(jnp.int32)
                new_cache = {"k": k.astype(cache["k"].dtype),
                             "v": v.astype(cache["v"].dtype), "kpos": kpos}
            else:                                    # ring: keep last Sc
                tail = jnp.arange(S - Sc, S)
                slots = tail % Sc
                kc = jnp.zeros_like(cache["k"]).at[:, slots].set(
                    k[:, tail].astype(cache["k"].dtype))
                vc = jnp.zeros_like(cache["v"]).at[:, slots].set(
                    v[:, tail].astype(cache["v"].dtype))
                kpos = jnp.full((Sc,), -10**9, jnp.int32).at[slots].set(
                    tail.astype(jnp.int32))
                new_cache = {"k": kc, "v": vc, "kpos": kpos}
    o = o.reshape(B, S, H * hd)
    x = x + o @ p["attn"]["o"]
    if rules is not None:
        seq_ax = "seq_act" if (opts and opts.seq_parallel) else None
        x = rules.shard(x, "batch", seq_ax, None)

    if "cross" in p:                                 # encoder-decoder cross attn
        xn2 = apply_norm(p["cross_norm"], x, cfg.norm)
        Bq = xn2.shape[0]
        qc = (xn2 @ p["cross"]["q"]).reshape(Bq, S, H, hd)
        if enc_out is not None:                      # fresh K/V from encoder
            Se = enc_out.shape[1]
            ck = (enc_out @ p["cross"]["k"]).reshape(Bq, Se, cfg.num_kv_heads, hd)
            cv = (enc_out @ p["cross"]["v"]).reshape(Bq, Se, cfg.num_kv_heads, hd)
        else:                                        # decode: from cache
            ck, cv = cache["ck"], cache["cv"]
            Se = ck.shape[1]
        epos = jnp.arange(Se)
        qpos_c = jnp.zeros((S,), jnp.int32)          # non-causal cross attn
        oc = attention(qc, ck, cv, qpos_c, epos, "attn", causal=False,
                       flash_threshold=fth, kv_block=kv_block)
        x = x + oc.reshape(Bq, S, H * hd) @ p["cross"]["o"]
        if new_cache is not None:
            new_cache["ck"], new_cache["cv"] = ck, cv
        elif cache is not None:
            new_cache = {"ck": ck, "cv": cv}

    xn3 = apply_norm(p["mlp_norm"], x, cfg.norm)
    if "moe" in p:
        y = moe_apply(p["moe"], xn3, cfg, rules,
                      overlap=(opts.moe_overlap if opts else False),
                      quantize=(opts.moe_quantize if opts else False),
                      backend=(opts.moe_backend if opts else "xla"))
    else:
        y = mlp_apply(p["mlp"], xn3, cfg.act)
    x = x + y
    if rules is not None:
        seq_ax = "seq_act" if (opts and opts.seq_parallel) else None
        x = rules.shard(x, "batch", seq_ax, None)
    return x, new_cache
