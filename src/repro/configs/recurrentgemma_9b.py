"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1 attn
(arXiv:2402.19427). 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Local attention window 2048.

38 layers is not divisible by the 3-block pattern; the released model runs the
(rglru, rglru, local_attn) cycle and truncates — we keep 38 layers with the
cycle truncated on the last repeat expressed as pattern repeats of the
divisible prefix (36) plus 2 extra recurrent layers folded into the pattern by
using a 19-layer half-cycle: (rglru, rglru, local_attn) * 12 + (rglru, rglru).
For scan-compatibility we express this as block_pattern of length 19 repeated
twice.
"""
from repro.configs.base import ModelConfig

_HALF = ("rglru", "rglru", "local_attn") * 6 + ("rglru",)   # 19 blocks

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=_HALF,
    window=2048,
    rope_theta=10000.0,
    norm="rmsnorm",
    act="gelu",                      # GeGLU: gated gelu (mlp uses gate*up like swiglu)
    tie_embeddings=True,
)
