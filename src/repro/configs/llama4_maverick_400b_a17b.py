"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
(hf:meta-llama/Llama-4-Maverick family).

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048, MoE on every
second layer (128 experts top-1 + shared expert), dense layers d_ff=16384.
iRoPE-style attention: chunked local attention (chunk 8192, RoPE) on 3 of 4
layers, NoPE full attention on the 4th — at decode the NoPE layers read a
sequence-sharded KV cache (O(S)/token), so long_500k runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=16384,                    # dense (non-MoE) layers
    vocab_size=202048,
    head_dim=128,
    block_pattern=("chunked_attn", "chunked_attn", "chunked_attn", "global_attn"),
    chunk=8192,
    rope_theta=500000.0,
    num_experts=128,
    experts_per_token=1,
    moe_every=2,
    moe_d_ff=8192,
    shared_expert=True,
    capacity_factor=1.25,
    ep_mode="alltoall",            # experts sharded over (pod, data); paper-style A2A dispatch
)
