from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced
from repro.configs.registry import ARCHS, get_arch, get_shape, cells

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "reduced",
    "ARCHS", "get_arch", "get_shape", "cells",
]
