"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

24L d_model=1024 4H (kv=4) d_ff=0 (xLSTM blocks carry their own up-projection)
vocab=50304. Alternating mLSTM/sLSTM (1:1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    block_pattern=("mlstm", "slstm"),
    use_rope=False,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)
