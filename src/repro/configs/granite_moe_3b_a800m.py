"""granite-moe-3b-a800m [moe] — 40 experts top-8
(hf:ibm-granite/granite-3.0-3b-a800m family).

32L d_model=1536 24H (GQA kv=8) expert d_ff=512 vocab=49155, MoE 40e top-8 on
every layer. Expert count padded to 48 for mesh divisibility (dummy experts
receive -inf router logits and no tokens).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    block_pattern=("attn",),
    num_experts=40,
    experts_per_token=8,
    moe_every=1,
    moe_d_ff=512,
    capacity_factor=1.5,
)
