"""whisper-large-v3 [audio] — encoder-decoder, conv frontend STUB (arXiv:2212.04356).

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866. The conv1d/mel frontend is a
stub: ``input_specs()`` provides precomputed frame embeddings (B, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                 # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    block_pattern=("attn",),
    use_rope=False,
    learned_pos=True,
    norm="layernorm",
    act="gelu",
    is_encoder_decoder=True,
    enc_layers=32,
    enc_seq=1500,
)
