"""--arch registry: id -> ModelConfig."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.llava_next_mistral_7b import CONFIG as LLAVA_NEXT_MISTRAL_7B
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from repro.configs.phi3_mini_3_8b import CONFIG as PHI3_MINI_3_8B
from repro.configs.llama3_2_1b import CONFIG as LLAMA3_2_1B
from repro.configs.granite_20b import CONFIG as GRANITE_20B
from repro.configs.stablelm_12b import CONFIG as STABLELM_12B
from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B

ARCHS = {
    c.name: c
    for c in [
        XLSTM_350M, WHISPER_LARGE_V3, LLAVA_NEXT_MISTRAL_7B, RECURRENTGEMMA_9B,
        PHI3_MINI_3_8B, LLAMA3_2_1B, GRANITE_20B, STABLELM_12B,
        LLAMA4_MAVERICK, GRANITE_MOE_3B,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def cells():
    """All (arch, shape) dry-run cells, with skip reasons for ineligible ones."""
    out = []
    for a, cfg in ARCHS.items():
        for s, shp in SHAPES.items():
            skip = None
            if s == "long_500k" and not cfg.supports_long_context:
                skip = "full-attention arch: long_500k needs sub-quadratic attention"
            out.append((a, s, skip))
    return out


__all__ = ["ARCHS", "SHAPES", "get_arch", "get_shape", "reduced", "cells"]
