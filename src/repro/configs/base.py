"""Model / shape configuration dataclasses for all assigned architectures.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
Param dims that must be sharded as jit *inputs* have to be divisible by the
mesh axis size, so vocab and expert counts are internally padded (``*_padded``
properties); logical sizes stay exact and padded slots are masked out.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Block kinds understood by the model builder.
ATTN_KINDS = ("attn", "local_attn", "chunked_attn", "global_attn")
RECURRENT_KINDS = ("mlstm", "slstm", "rglru")
BLOCK_KINDS = ATTN_KINDS + RECURRENT_KINDS


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # block structure: cycled over layers
    block_pattern: tuple = ("attn",)
    window: int = 0                  # local attention window
    chunk: int = 0                   # chunked attention chunk size
    rope_theta: float = 10000.0
    use_rope: bool = True
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    learned_pos: bool = False        # learned absolute position embeddings
    max_position: int = 0            # rows of learned pos table (0 -> from shape)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # MoE replaces MLP on layers with (idx % moe_every == moe_every-1)
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    ep_mode: str = "replicated"      # replicated (psum over TP) | alltoall (EP over data)
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 0                 # stub conv-frontend output frames
    # vlm stub
    num_patch_tokens: int = 0        # precomputed patch embeddings prepended
    # recurrence
    conv_width: int = 4              # temporal conv width (rglru branch)
    mlstm_chunk: int = 128           # chunkwise-parallel chunk for mLSTM
    # numerics
    dtype: str = "bfloat16"
    # sharding pad granularity (model-axis size the padded dims must divide by)
    pad_to: int = 16

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, max(256, self.pad_to))

    @property
    def num_experts_padded(self) -> int:
        if self.num_experts == 0:
            return 0
        return _round_up(self.num_experts, self.pad_to)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_recurrent(self) -> bool:
        return any(k in RECURRENT_KINDS for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block does unbounded full attention (long_500k eligible)."""
        return all(k not in ("attn",) for k in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """long_500k cell eligibility: recurrent/local/chunked archs.

        ``global_attn`` (NoPE full-attention layers in llama4's iRoPE pattern)
        is allowed because at *decode* it is O(S) per token over a
        sequence-sharded KV cache; pure full-attention archs are skipped.
        """
        return all(k not in ("attn",) for k in self.block_pattern) and not self.is_encoder_decoder

    def block_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def layer_is_moe(self, layer_idx: int) -> bool:
        return self.is_moe and (layer_idx % self.moe_every == self.moe_every - 1)

    @property
    def repeat_unit(self) -> int:
        """Layers per scan step: lcm of the block pattern and MoE interleave."""
        unit = len(self.block_pattern)
        if self.is_moe:
            unit = math.lcm(unit, self.moe_every)
        assert self.num_layers % unit == 0, (self.name, self.num_layers, unit)
        return unit

    @property
    def num_repeats(self) -> int:
        return self.num_layers // self.repeat_unit

    def param_count(self) -> int:
        """Analytic parameter count (logical, unpadded)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d          # token embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d     # lm head
        if self.learned_pos:
            n += (self.max_position or 4096) * d
        mlp_mult = 3 if self.act == "swiglu" else 2
        for i in range(self.num_layers):
            kind = self.block_kind(i)
            if kind in ATTN_KINDS:
                n += d * self.num_heads * hd * 2          # q, o
                n += d * self.num_kv_heads * hd * 2       # k, v
                n += d                                    # pre-norm
                if self.layer_is_moe(i):
                    n += d * self.num_experts             # router
                    n += self.num_experts * d * self.moe_d_ff * mlp_mult
                    if self.shared_expert:
                        n += d * self.moe_d_ff * mlp_mult
                else:
                    n += d * self.d_ff * mlp_mult
                n += d                                    # mlp pre-norm
            elif kind == "rglru":
                # griffin recurrent block: 2 in-proj, conv, gates, out-proj + mlp
                n += d * d * 3 + d * self.conv_width + 2 * d * d + 2 * d
                n += d * self.d_ff * mlp_mult + d
            elif kind == "mlstm":
                du = 2 * d
                n += d * du * 2 + du * (3 * (du // max(1, self.num_heads))) + du * d + 2 * d
            elif kind == "slstm":
                n += d * 4 * d + 4 * d * (d // max(1, self.num_heads)) + d * int(4 / 3 * d) * 2 + 2 * d
        if self.is_encoder_decoder:
            # encoder layers (self-attn + mlp) and decoder cross-attn
            enc = self.enc_layers * (d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2
                                     + d * self.d_ff * mlp_mult + 2 * d)
            cross = self.num_layers * (d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2 + d)
            n += enc + cross + self.enc_seq * d  # enc pos table
        n += d                                    # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        mlp_mult = 3 if self.act == "swiglu" else 2
        expert_p = self.num_experts * self.d_model * self.moe_d_ff * mlp_mult
        active_p = self.experts_per_token * self.d_model * self.moe_d_ff * mlp_mult
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.layer_is_moe(i))
        return full - n_moe_layers * (expert_p - active_p)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family/pattern as ``cfg``."""
    unit = cfg.repeat_unit
    small = dict(
        num_layers=unit,             # one repeat unit keeps the pattern intact
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        window=min(cfg.window, 16) if cfg.window else 0,
        chunk=min(cfg.chunk, 32) if cfg.chunk else 0,
        enc_layers=min(cfg.enc_layers, 2),
        enc_seq=min(cfg.enc_seq, 8),
        num_patch_tokens=min(cfg.num_patch_tokens, 4),
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        mlstm_chunk=8,
        conv_width=cfg.conv_width,
        pad_to=2,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
