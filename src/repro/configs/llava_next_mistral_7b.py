"""llava-next-mistral-7b [vlm] — mistral backbone + anyres tiling STUB.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. The vision tower /
anyres tiling is a stub: ``input_specs()`` provides precomputed patch
embeddings (B, 576, d_model) prepended to the token sequence.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    num_patch_tokens=576,
)
