"""Atomic, elastic checkpointing.

* Atomic: write to ``step_NNNN.tmp`` then ``os.replace`` + manifest update —
  a preempted writer never corrupts the latest checkpoint.
* Elastic: arrays are saved as *global* (unsharded) numpy arrays keyed by
  pytree path, so a restart may reload under a different mesh/device count —
  re-sharding happens at ``device_put`` against the new mesh's specs.
* The data pipeline is index-addressable, so the manifest's step counter is
  the only data-state needed for an exact resume.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax
import numpy as np


def _flatten(tree, *, view_bf16=False):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if view_bf16 and arr.dtype.name == "bfloat16":   # npz has no bf16
            arr = arr.view(np.uint16)
        out[key] = arr
    return out, treedef


def save_checkpoint(ckpt_dir, step, state, *, keep=3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(state, view_bf16=True)
    tmp = ckpt_dir / f"step_{step:08d}.npz.tmp"
    final = ckpt_dir / f"step_{step:08d}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)                      # atomic publish
    manifest = ckpt_dir / "manifest.json"
    mtmp = ckpt_dir / "manifest.json.tmp"
    mtmp.write_text(json.dumps({"latest_step": step,
                                "file": final.name}))
    os.replace(mtmp, manifest)
    # retention
    ckpts = sorted(ckpt_dir.glob("step_*.npz"))
    for old in ckpts[:-keep]:
        old.unlink()
    return final


def latest_step(ckpt_dir):
    manifest = pathlib.Path(ckpt_dir) / "manifest.json"
    if not manifest.exists():
        return None
    return json.loads(manifest.read_text())["latest_step"]


def restore_checkpoint(ckpt_dir, state_like, *, step=None, shardings=None):
    """Restore into the structure of ``state_like``; optionally re-shard
    against a (possibly different) mesh via ``shardings``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    data = np.load(ckpt_dir / f"step_{step:08d}.npz")
    flat, treedef = _flatten(state_like)
    leaves = []
    for key, like in flat.items():
        arr = data[key]
        like_np = np.asarray(like)
        if like_np.dtype.name == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(like_np.dtype)
        assert arr.shape == like_np.shape, (key, arr.shape, like_np.shape)
        leaves.append(arr.astype(like_np.dtype))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, step
