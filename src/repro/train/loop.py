"""Training loop: sharded train_step + checkpoint/restart + preemption +
straggler watchdog. The same loop drives the 100M-parameter e2e example and
the smoke tests."""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data import DataConfig, SyntheticTokenPipeline
from repro.dist.sharding import Rules, sanitize_specs
from repro.models import StepOptions, init_params, param_specs, train_loss
from repro.optim import AdamWConfig, adamw_update, init_opt_state, \
    opt_state_specs
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import PreemptionGuard, StragglerWatchdog


@dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    opts: StepOptions = field(default_factory=StepOptions)
    opt: AdamWConfig = field(default_factory=AdamWConfig)


def build_state(key, cfg, mesh, rules):
    params = init_params(key, cfg)
    opt_state = init_opt_state(params)
    if mesh is not None:
        p_sds = jax.eval_shape(lambda k: init_params(k, cfg), key)
        specs = sanitize_specs(param_specs(cfg, rules), p_sds, mesh)
        o_specs = opt_state_specs(specs, p_sds, rules)
        params = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda s: isinstance(s, P)))
        opt_state = jax.device_put(opt_state, jax.tree.map(
            lambda s: NamedSharding(mesh, s), o_specs,
            is_leaf=lambda s: isinstance(s, P)))
        return params, opt_state, specs, o_specs
    return params, opt_state, None, None


def train(cfg, tcfg: TrainConfig, mesh=None, *, resume=True, verbose=True,
          max_steps_this_run=None):
    """Returns (losses, last_step, state). Interruptible + resumable."""
    rules = Rules(mesh, "train") if mesh is not None else None
    key = jax.random.PRNGKey(tcfg.seed)
    params, opt_state, specs, o_specs = build_state(key, cfg, mesh, rules)

    start = 0
    if resume and tcfg.ckpt_dir:
        shardings = None
        if mesh is not None:
            shardings = {"params": jax.tree.map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda s: isinstance(s, P)),
                "opt": jax.tree.map(
                lambda s: NamedSharding(mesh, s), o_specs,
                is_leaf=lambda s: isinstance(s, P))}
        restored, step = restore_checkpoint(
            tcfg.ckpt_dir, {"params": params, "opt": opt_state},
            shardings=shardings)
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = step
            if verbose:
                print(f"[train] resumed from step {start}")

    data = SyntheticTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
        global_batch=tcfg.global_batch, seed=tcfg.seed,
        frames=cfg.enc_seq if cfg.is_encoder_decoder else 0,
        patches=cfg.num_patch_tokens, d_model=cfg.d_model))

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, rules, tcfg.opts))(params)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                tcfg.opt)
        return params, opt_state, loss, gnorm

    losses = []
    watchdog = StragglerWatchdog()
    end = tcfg.steps if max_steps_this_run is None else \
        min(tcfg.steps, start + max_steps_this_run)
    with PreemptionGuard() as guard:
        for step in range(start, end):
            t0 = time.perf_counter()
            batch = data.batch(step)
            params, opt_state, loss, gnorm = step_fn(params, opt_state, batch)
            loss = float(loss)
            losses.append(loss)
            watchdog.record(time.perf_counter() - t0)
            if verbose and (step % tcfg.log_every == 0):
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(gnorm):.3f}")
            done = step + 1
            if tcfg.ckpt_dir and (done % tcfg.ckpt_every == 0
                                  or done == tcfg.steps or guard.requested):
                save_checkpoint(tcfg.ckpt_dir, done,
                                {"params": params, "opt": opt_state})
            if guard.requested:
                if verbose:
                    print(f"[train] preemption requested — saved at {done}")
                break
    return losses, (step + 1 if losses else start), (params, opt_state)
