"""Fault tolerance & elasticity for the training loop.

Mechanisms (designed for 1000+ nodes; exercised in tests on host devices):

* **Preemption-aware checkpointing** — SIGTERM/SIGINT installs a "save at the
  next step boundary" flag; the loop drains and persists atomically.
* **Checkpoint/restart** — pure function of (checkpoint, step): the
  index-addressable data pipeline makes resume exact (tests assert
  bit-equal losses between an uninterrupted run and a killed+resumed run).
* **Elastic re-mesh** — checkpoints store global arrays; on restart with a
  different device count the state is re-sharded under the new mesh
  (tests restore a 4-device run onto 2 devices).
* **Straggler mitigation** — synchronous SPMD steps cannot proceed without
  every worker; the watchdog measures per-step wall time against a rolling
  median and flags persistent stragglers for the scheduler to replace
  (replacement itself = preempt + elastic restart, both implemented).
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field


class PreemptionGuard:
    """Installs signal handlers that request a graceful save+exit."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


@dataclass
class StragglerWatchdog:
    """Rolling-median step-time monitor. A worker consistently slower than
    ``threshold`` x median is reported as a straggler.

    ``record`` accepts the round's tick count from the collective
    schedules (``issued_rounds()`` / ``completion_ticks()``): wall time is
    normalized to per-tick before the median compare, so a structurally
    bigger round (more DMA events) is never mistaken for a slower rank.

    Incidents live in a sliding window of the last ``incident_window``
    records — blips age out instead of latching forever, and
    ``should_replace`` asks for ``replace_after`` incidents *within the
    window*: a persistent straggler keeps it armed, transient jitter
    decays back to healthy. ``reset()`` clears the history after a
    replacement so the substitute rank starts clean."""
    window: int = 32
    threshold: float = 2.0
    min_samples: int = 8
    incident_window: int = 16
    replace_after: int = 3
    times: list = field(default_factory=list)
    incidents: int = 0            # lifetime total (monotonic, diagnostics)
    _step: int = 0
    _incident_steps: list = field(default_factory=list)

    def record(self, step_time_s: float, ticks: int = 1) -> bool:
        """Returns True if this step is a straggler incident."""
        t = float(step_time_s) / max(1, int(ticks))
        self._step += 1
        self._prune()
        self.times.append(t)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < self.min_samples:
            return False
        med = statistics.median(self.times[:-1])
        if t > self.threshold * med:
            self.incidents += 1
            self._incident_steps.append(self._step)
            return True
        return False

    def _prune(self):
        horizon = self._step - self.incident_window
        while self._incident_steps and self._incident_steps[0] <= horizon:
            self._incident_steps.pop(0)

    @property
    def recent_incidents(self):
        """Incidents still inside the sliding window."""
        self._prune()
        return len(self._incident_steps)

    @property
    def should_replace(self):
        return self.recent_incidents >= self.replace_after

    def reset(self):
        """Post-replacement: the substitute rank starts with no history."""
        self.times.clear()
        self._incident_steps.clear()
        self.incidents = 0
        self._step = 0


@dataclass
class ElasticController:
    """Closes the fault loop across train/serve and the collective
    kernels: one :class:`StragglerWatchdog` per rank consumes per-round
    tick accounting from the schedules, a rank whose watchdog trips is
    dropped from the live set, and :meth:`degrade` maps any
    ``CollectiveSchedule`` (or workload) onto the survivors — drop the
    rank, degrade the schedules, keep serving.

    Fleet health is exported through ``metrics`` (a
    ``core.telemetry.MetricsRegistry``, one created per controller
    otherwise): straggler-incident and dropped-rank counters, a
    ``elastic.live_ranks`` gauge, per-rank step-time histograms, and a
    degrade-event counter — ``controller.metrics.snapshot()`` is the
    JSON-ready fleet view."""
    n_ranks: int
    window: int = 32
    threshold: float = 2.0
    min_samples: int = 8
    incident_window: int = 16
    replace_after: int = 3
    metrics: object = None

    def __post_init__(self):
        if self.metrics is None:
            from repro.core.telemetry import MetricsRegistry
            self.metrics = MetricsRegistry()
        self._live = list(range(self.n_ranks))
        self.watchdogs = {
            r: StragglerWatchdog(
                window=self.window, threshold=self.threshold,
                min_samples=self.min_samples,
                incident_window=self.incident_window,
                replace_after=self.replace_after)
            for r in self._live}
        self.metrics.gauge("elastic.live_ranks").set(len(self._live))

    @property
    def live_ranks(self):
        return tuple(self._live)

    def observe_round(self, times_by_rank, ticks: int = 1):
        """Feed one collective round's per-rank wall times (seconds);
        ``ticks`` is the round's event count from the schedule. Returns
        the ranks dropped by this observation (usually empty)."""
        dropped = []
        for r in sorted(times_by_rank):
            if r not in self._live:
                continue
            self.metrics.histogram("elastic.step_ms").observe(
                float(times_by_rank[r]) * 1e3)
            if self.watchdogs[r].record(times_by_rank[r], ticks=ticks):
                self.metrics.counter("elastic.straggler_incidents").inc()
            if self.watchdogs[r].should_replace:
                self.drop(r)
                dropped.append(r)
        return tuple(dropped)

    def drop(self, rank):
        """Remove ``rank`` from the membership (idempotent); refuses to
        drop the last survivor — a collective needs one."""
        if rank in self._live:
            if len(self._live) == 1:
                raise RuntimeError("cannot drop the last live rank")
            self._live.remove(rank)
            self.watchdogs[rank].reset()
            self.metrics.counter("elastic.ranks_dropped").inc()
            self.metrics.gauge("elastic.live_ranks").set(len(self._live))

    def degrade(self, schedule_or_workload):
        """Map a ``CollectiveSchedule`` (or a ``Workload``) onto the
        current live set via its ``degrade(live_ranks)`` contract."""
        self.metrics.counter("elastic.degrade_events").inc()
        return schedule_or_workload.degrade(self.live_ranks)
