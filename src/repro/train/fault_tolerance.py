"""Fault tolerance & elasticity for the training loop.

Mechanisms (designed for 1000+ nodes; exercised in tests on host devices):

* **Preemption-aware checkpointing** — SIGTERM/SIGINT installs a "save at the
  next step boundary" flag; the loop drains and persists atomically.
* **Checkpoint/restart** — pure function of (checkpoint, step): the
  index-addressable data pipeline makes resume exact (tests assert
  bit-equal losses between an uninterrupted run and a killed+resumed run).
* **Elastic re-mesh** — checkpoints store global arrays; on restart with a
  different device count the state is re-sharded under the new mesh
  (tests restore a 4-device run onto 2 devices).
* **Straggler mitigation** — synchronous SPMD steps cannot proceed without
  every worker; the watchdog measures per-step wall time against a rolling
  median and flags persistent stragglers for the scheduler to replace
  (replacement itself = preempt + elastic restart, both implemented).
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field


class PreemptionGuard:
    """Installs signal handlers that request a graceful save+exit."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


@dataclass
class StragglerWatchdog:
    """Rolling-median step-time monitor. A worker consistently slower than
    ``threshold`` x median is reported as a straggler."""
    window: int = 32
    threshold: float = 2.0
    min_samples: int = 8
    times: list = field(default_factory=list)
    incidents: int = 0

    def record(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler incident."""
        self.times.append(step_time_s)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < self.min_samples:
            return False
        med = statistics.median(self.times[:-1])
        if step_time_s > self.threshold * med:
            self.incidents += 1
            return True
        return False

    @property
    def should_replace(self):
        return self.incidents >= 3
