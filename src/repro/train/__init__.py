from repro.train.checkpoint import save_checkpoint, restore_checkpoint, \
    latest_step
from repro.train.loop import TrainConfig, train

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "TrainConfig", "train"]
