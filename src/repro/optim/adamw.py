"""AdamW with f32 master weights + moments, global-norm clipping, cosine
schedule, and ZeRO-style state sharding (moments/master additionally sharded
over the data axis via ``zero_spec``)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.dist.sharding import zero_spec

F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, F32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    master = jax.tree.map(lambda p: p.astype(F32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros),
            "master": master, "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_spec_tree, param_shapes, rules):
    """Specs for the opt state: params' specs + ZeRO extra data-sharding."""
    from jax.sharding import PartitionSpec as P

    def z(spec, sds):
        return zero_spec(spec, sds.shape, rules)

    zt = jax.tree.map(z, param_spec_tree, param_shapes,
                      is_leaf=lambda s: isinstance(s, P))
    return {"m": zt, "v": zt, "master": zt, "step": P()}


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    # global-norm clip in f32
    gsq = sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    c1 = 1 - cfg.b1 ** step.astype(F32)
    c2 = 1 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v, w):
        g = g.astype(F32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / c1
        vh = v2 / c2
        w2 = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
        return w2.astype(p.dtype), m2, v2, w2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], state["master"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_w = jax.tree.map(lambda t: t[3], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "master": new_w, "step": step}, gnorm
