from repro.optim.adamw import (AdamWConfig, init_opt_state, adamw_update,
                               opt_state_specs, lr_at)

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "opt_state_specs",
           "lr_at"]
