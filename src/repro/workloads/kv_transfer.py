"""Workload 3: KV-cache transfer for disaggregated prefill->decode serving
(paper Table 4 row 3, Appendix M).

Host baseline: the prefill rank computes K and V projections, then a single
host-sequenced transfer moves both — the network idles during compute and
compute idles during the transfer (the compute-to-send gap).

Device-initiated builds (repro.kernels.kv_shuttle, realized against the
shared ``core/schedule.py::RingSchedule`` — the n=2 degenerate ring): the
chained kernel — K GEMM -> start K send -> V GEMM (overlapping K's flight)
-> V send+signal — and the TILE_FUSED + COUNTER point (the FLUX point for
the shuttle): ``kv_chunk``-row K/V GEMM tiles whose sends issue the moment
each tile is ready, under a ``contexts``-deep send window, with the decode
rank ticking arrivals off one chunk at a time. The decode rank waits
entirely on-device either way. XLA STREAM_SPLIT build: two independent
ppermute chains let XLA overlap K's transfer with V's GEMM at graph level.

``kernel_knobs`` (the ``Workload`` protocol's search contract) is the
single directive→knob mapping both ``build()`` and ``analytic_cost()``
consult; the ``chained`` and ``kv_chunk`` tunables are refinable by the
slow path's diff patches (``TUNABLES`` grids).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.cost_model import (CostBreakdown, CostSegment,
                                   per_tile_exposed_s, window_stall_factor)
from repro.core.design_space import Directive
from repro.core.schedule import make_ring_schedule
from repro.kernels.kv_shuttle import kv_shuttle as shuttle_kernel
from repro.workloads.base import (KERNEL_LAUNCH, SIGNAL_OVERHEAD, TILE_SYNC,
                                  BARRIER_OVERHEAD, Workload, register)
from repro.compat import shard_map


@register
class KVTransfer(Workload):
    name = "kv_transfer"
    ring_topology = False
    kernelizable = True

    def __init__(self, T=4096, d=4096, dk=512, axis="x", solo=False):
        # ``solo``: the degraded single-tier fallback — one rank lost, the
        # survivor runs prefill and decode colocated, so the K/V projections
        # stay local and the shuttle disappears (degrade, don't hang)
        self.solo = bool(solo)
        self.n_dev = 1 if solo else 2
        self.T = T
        self.d = d
        self.dk = dk
        self.axis = axis

    def example_inputs(self, key, mesh, T=None):
        T = T or min(self.T, 128)
        ks = jax.random.split(key, 3)
        x_real = jax.random.normal(ks[0], (T, self.d // 8), jnp.float32)
        x = x_real[None] if self.solo \
            else jnp.stack([x_real, jnp.zeros_like(x_real)])
        wk = jax.random.normal(ks[1], (self.d // 8, self.dk // 4), jnp.float32)
        wv = jax.random.normal(ks[2], (self.d // 8, self.dk // 4), jnp.float32)
        return x, wk, wv

    def reference(self, x, wk, wv):
        k = x[0] @ wk
        v = x[0] @ wv
        if self.solo:
            return k[None], v[None]
        z = jnp.zeros_like(k)
        return jnp.stack([z, k]), jnp.stack([jnp.zeros_like(v), v])

    # ------------------------------------------- fault contract (core/faults)
    def degrade(self, live_ranks):
        """Losing either tier collapses the disaggregation: the survivor
        serves prefill+decode colocated (the ``solo`` fallback — K/V stay
        local, the shuttle disappears). The recovery term of ``fault_cost``
        charges re-materializing the dead tier's cache over ICI."""
        from repro.core.schedule import check_live
        live = check_live(live_ranks, self.n_dev)
        if len(live) == self.n_dev:
            return self
        return type(self)(T=self.T, d=self.d, dk=self.dk, axis=self.axis,
                          solo=True)

    def state_bytes_per_rank(self):
        # prefill activations + the K/V cache of the handoff (f32)
        return 4 * (self.T * self.d + 2 * self.T * self.dk)

    # ------------------------------------------------------------- builders
    def host_baseline(self, mesh):
        if self.solo:
            return self._solo_local()
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None), P(None, None)),
                           out_specs=(P(axis), P(axis)), check_vma=False)
        def run(x, wk, wv):
            xs = x[0]
            me = jax.lax.axis_index(axis)
            k = xs @ wk
            v = xs @ wv
            kv = jnp.concatenate([k, v], axis=-1)     # one bundled transfer
            kv = jax.lax.ppermute(kv, axis, [(0, 1)])
            dk = k.shape[-1]
            k_out = jnp.where(me == 1, kv[:, :dk], 0.0)
            v_out = jnp.where(me == 1, kv[:, dk:], 0.0)
            return k_out[None], v_out[None]

        return run

    def _stream_split(self, mesh):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None), P(None, None)),
                           out_specs=(P(axis), P(axis)), check_vma=False)
        def run(x, wk, wv):
            xs = x[0]
            me = jax.lax.axis_index(axis)
            k = xs @ wk
            k_sent = jax.lax.ppermute(k, axis, [(0, 1)])   # K flies while ...
            v = xs @ wv                                    # ... V computes
            v_sent = jax.lax.ppermute(v, axis, [(0, 1)])
            k_out = jnp.where(me == 1, k_sent, 0.0)
            v_out = jnp.where(me == 1, v_sent, 0.0)
            return k_out[None], v_out[None]

        return run

    # directive -> kernel-knob mapping shared by build() and analytic_cost()
    # (the Workload.kernel_knobs search contract, docs/kernels.md)
    def kernel_knobs(self, d: Directive):
        k = super().kernel_knobs(d)      # chained/kv_chunk (raw) + contexts
        fused = (d.placement == "TILE_FUSED" and d.completion != "BARRIER")
        # the K→V signal chain: placement decides the default (BARRIER
        # forces the conservative sequential shape, like every other
        # workload's BARRIER override), and the `chained` tunable lets a
        # diff patch flip it in place. None (the seeded default) means
        # "unset" — fast_path seeds directives with default_tunables, and
        # a stored None must not shadow the placement-derived default.
        ch = k["chained"]
        if ch is None:
            ch = (d.placement in ("STREAM_SPLIT", "TILE_PIPELINED",
                                  "TILE_FUSED")
                  and d.ordering != "ACQREL" and d.completion != "BARRIER")
        k.update(
            # per-tile fused K/V GEMM + send chain (the shuttle FLUX point)
            fused=fused,
            counter=(d.completion == "COUNTER" and fused),
            chained=bool(ch))
        return k

    def collective_schedule(self, d: Directive):
        # the degenerate 2-rank shuttle ring at the deployment tile count
        # — l0 (core/verify.py) statically checks it ahead of l1 build;
        # the solo tier moves nothing and verifies vacuously
        if d.backend == "XLA_COLLECTIVE" or self.n_dev < 2:
            return None
        k = self.kernel_knobs(d)
        return make_ring_schedule(2, self.T, k["kv_chunk"],
                                  fused=k["fused"])

    def _solo_local(self):
        # the single-tier fallback: both projections local, no collective
        def run(x, wk, wv):
            return (x[0] @ wk)[None], (x[0] @ wv)[None]

        return run

    def build(self, d: Directive, mesh):
        if self.solo:
            return self._solo_local()
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                return self._stream_split(mesh)
            return self.host_baseline(mesh)
        k = self.kernel_knobs(d)

        def run(x, wk, wv):
            return shuttle_kernel(x, wk, wv, mesh, axis=self.axis,
                                  chained=k["chained"], fused=k["fused"],
                                  counter=k["counter"],
                                  kv_chunk=k["kv_chunk"],
                                  contexts=k["contexts"])

        return run

    def default_tunables(self):
        return {"chained": None, "kv_chunk": 64}

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        return self.cost_breakdown(d, hw).total

    def cost_breakdown(self, d: Directive, hw) -> CostBreakdown:
        Seg = CostSegment
        T, dd, dk = self.T, self.d, self.dk
        t_gemm = 2.0 * T * dd * dk / hw.chip.peak_bf16_flops
        t_send = T * dk * 2 / hw.chip.ici_link_bw
        if self.solo:
            # colocated fallback: both GEMMs, no wire (fault_cost adds the
            # dead tier's cache recovery on top)
            return CostBreakdown(segments=(
                Seg("kv_gemms", 2 * t_gemm, "compute"),
                Seg("launch", KERNEL_LAUNCH, "launch"),
            ), meta={"path": "solo"})
        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                # K send overlaps V GEMM; V send exposed
                return CostBreakdown(segments=(
                    Seg("k_gemm", t_gemm, "compute"),
                    Seg("k_send_overlap", max(t_send, t_gemm), "overlap",
                        meta={"wire_s": t_send, "compute_s": t_gemm}),
                    Seg("v_send", t_send, "wire"),
                    Seg("sync", sync, "sync"),
                    Seg("launch", 2 * KERNEL_LAUNCH, "launch"),
                ), meta={"path": "xla_stream_split"})
            # bundled: both GEMMs then one 2x transfer
            return CostBreakdown(segments=(
                Seg("kv_gemms", 2 * t_gemm, "compute"),
                Seg("kv_send", 2 * t_send, "wire"),
                Seg("sync", sync, "sync"),
                Seg("launch", 2 * KERNEL_LAUNCH, "launch"),
            ), meta={"path": "xla_host"})
        k = self.kernel_knobs(d)
        if k["fused"]:
            # shuttle FLUX credit: tile c's send hides behind tile c+1's
            # GEMM; only the startup tile and the final exposed tail (per
            # chunk, scaled by the window recycle stall) stay serial. The
            # schedule charges TILE_SYNC per issued round and per tick.
            sched = make_ring_schedule(2, T, k["kv_chunk"], fused=True)
            startup = 2 * t_gemm / sched.nc
            span = max(2 * t_gemm, startup + 2 * t_send)
            exposed = window_stall_factor(k["contexts"]) \
                * per_tile_exposed_s(2 * T * dk * 2, hw.chip.ici_link_bw,
                                     sched.nc)
            fixed = (sched.issued_rounds()
                     + sched.completion_ticks(k["counter"])) * TILE_SYNC
            return CostBreakdown(segments=(
                Seg("fused_span", span, "overlap",
                    meta={"compute_s": 2 * t_gemm,
                          "wire_s": startup + 2 * t_send}),
                Seg("window_stall", exposed, "stall",
                    meta={"contexts": k["contexts"]}),
                Seg("tile_sync", fixed, "sync",
                    meta={"issued_rounds": sched.issued_rounds(),
                          "ticks": sched.completion_ticks(k["counter"])}),
                Seg("launch", KERNEL_LAUNCH, "launch"),
            ), schedule=sched, knobs=k, meta={"path": "kernel_fused"})
        if k["chained"]:
            return CostBreakdown(segments=(
                Seg("k_gemm", t_gemm, "compute"),
                Seg("k_send_overlap", max(t_send, t_gemm), "overlap",
                    meta={"wire_s": t_send, "compute_s": t_gemm}),
                Seg("v_send", t_send, "wire"),
                Seg("sync", sync, "sync"),
                Seg("launch", KERNEL_LAUNCH, "launch"),
            ), knobs=k, meta={"path": "kernel_chained"})
        return CostBreakdown(segments=(
            Seg("kv_gemms", 2 * t_gemm, "compute"),
            Seg("kv_send", 2 * t_send, "wire"),
            Seg("sync", sync, "sync"),
            Seg("launch", KERNEL_LAUNCH, "launch"),
        ), knobs=k, meta={"path": "kernel_deferred"})
