"""Workload 3: KV-cache transfer for disaggregated prefill->decode serving
(paper Table 4 row 3, Appendix M).

Host baseline: the prefill rank computes K and V projections, then a single
host-sequenced transfer moves both — the network idles during compute and
compute idles during the transfer (the compute-to-send gap).

Device-initiated build: the chained kernel (repro.kernels.kv_shuttle) —
K GEMM -> start K send -> V GEMM (overlapping K's flight) -> V send+signal;
the decode rank waits on-device. XLA STREAM_SPLIT build: two independent
ppermute chains let XLA overlap K's transfer with V's GEMM at graph level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.design_space import Directive
from repro.kernels.kv_shuttle import kv_shuttle as shuttle_kernel
from repro.workloads.base import (KERNEL_LAUNCH, SIGNAL_OVERHEAD,
                                  BARRIER_OVERHEAD, Workload, register)
from repro.compat import shard_map


@register
class KVTransfer(Workload):
    name = "kv_transfer"
    ring_topology = False
    kernelizable = True

    def __init__(self, T=4096, d=4096, dk=512, axis="x"):
        self.n_dev = 2
        self.T = T
        self.d = d
        self.dk = dk
        self.axis = axis

    def example_inputs(self, key, mesh, T=None):
        T = T or min(self.T, 128)
        ks = jax.random.split(key, 3)
        x_real = jax.random.normal(ks[0], (T, self.d // 8), jnp.float32)
        x = jnp.stack([x_real, jnp.zeros_like(x_real)])
        wk = jax.random.normal(ks[1], (self.d // 8, self.dk // 4), jnp.float32)
        wv = jax.random.normal(ks[2], (self.d // 8, self.dk // 4), jnp.float32)
        return x, wk, wv

    def reference(self, x, wk, wv):
        k = x[0] @ wk
        v = x[0] @ wv
        z = jnp.zeros_like(k)
        return jnp.stack([z, k]), jnp.stack([jnp.zeros_like(v), v])

    # ------------------------------------------------------------- builders
    def host_baseline(self, mesh):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None), P(None, None)),
                           out_specs=(P(axis), P(axis)), check_vma=False)
        def run(x, wk, wv):
            xs = x[0]
            me = jax.lax.axis_index(axis)
            k = xs @ wk
            v = xs @ wv
            kv = jnp.concatenate([k, v], axis=-1)     # one bundled transfer
            kv = jax.lax.ppermute(kv, axis, [(0, 1)])
            dk = k.shape[-1]
            k_out = jnp.where(me == 1, kv[:, :dk], 0.0)
            v_out = jnp.where(me == 1, kv[:, dk:], 0.0)
            return k_out[None], v_out[None]

        return run

    def _stream_split(self, mesh):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None), P(None, None)),
                           out_specs=(P(axis), P(axis)), check_vma=False)
        def run(x, wk, wv):
            xs = x[0]
            me = jax.lax.axis_index(axis)
            k = xs @ wk
            k_sent = jax.lax.ppermute(k, axis, [(0, 1)])   # K flies while ...
            v = xs @ wv                                    # ... V computes
            v_sent = jax.lax.ppermute(v, axis, [(0, 1)])
            k_out = jnp.where(me == 1, k_sent, 0.0)
            v_out = jnp.where(me == 1, v_sent, 0.0)
            return k_out[None], v_out[None]

        return run

    def build(self, d: Directive, mesh):
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                return self._stream_split(mesh)
            return self.host_baseline(mesh)
        chained = d.placement in ("STREAM_SPLIT", "TILE_PIPELINED",
                                  "TILE_FUSED") and d.ordering != "ACQREL"

        def run(x, wk, wv):
            return shuttle_kernel(x, wk, wv, mesh, axis=self.axis,
                                  chained=chained)

        return run

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        T, dd, dk = self.T, self.d, self.dk
        t_gemm = 2.0 * T * dd * dk / hw.chip.peak_bf16_flops
        t_send = T * dk * 2 / hw.chip.ici_link_bw
        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        chained = d.placement in ("STREAM_SPLIT", "TILE_PIPELINED",
                                  "TILE_FUSED") and d.ordering != "ACQREL"
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                # K send overlaps V GEMM; V send exposed
                return (t_gemm + max(t_send, t_gemm) + t_send + sync
                        + 2 * KERNEL_LAUNCH)
            # bundled: both GEMMs then one 2x transfer
            return 2 * t_gemm + 2 * t_send + sync + 2 * KERNEL_LAUNCH
        if chained:
            return t_gemm + max(t_send, t_gemm) + t_send + sync + KERNEL_LAUNCH
        return 2 * t_gemm + 2 * t_send + sync + KERNEL_LAUNCH
