"""Workload protocol for the co-design search.

A workload exposes:
  * ``reference``       — pure-jnp oracle over global arrays,
  * ``host_baseline``   — the host-driven input program (XLA collectives,
                          strictly sequenced; what a user writes before
                          device-initiated redesign),
  * ``build(directive)``— the directive-realized implementation (the bounded
                          operator's output),
  * ``kernel_knobs``    — the single directive→kernel-knob mapping both
                          ``build()`` and ``analytic_cost()`` consult for
                          the kernelized (PALLAS_RDMA/HYBRID) points: the
                          search contract of docs/kernels.md. The base
                          default maps every ``default_tunables()`` entry
                          (directive tunables win — the grids live in
                          ``design_space.TUNABLES``) plus the shared
                          ``contexts`` dimension; workloads override to add
                          their placement/completion realizations, and
  * ``analytic_cost``   — the l3 roofline model of one step at the paper's
                          full deployment shape (this container is CPU-only,
                          so empirical latency is replaced by a v5e roofline
                          composition; see DESIGN.md §2).

Builders must be *semantics-preserving*: every directive that validates for
the workload's traits produces the same numbers (cascade l2 checks this).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.design_space import Directive, violations

WORKLOADS = {}


def register(cls):
    WORKLOADS[cls.name] = cls
    return cls


def get_workload(name: str, **kw):
    return WORKLOADS[name](**kw)


# rough per-event overheads (seconds) used by the analytic l3 model
BARRIER_OVERHEAD = 2e-6          # global rendezvous per occurrence
SIGNAL_OVERHEAD = 0.3e-6         # point-to-point semaphore wait
KERNEL_LAUNCH = 4e-6             # host-driven launch gap per phase
TILE_SYNC = 0.5e-6               # per-tile counter/semaphore check


@dataclass
class Workload:
    name = "abstract"
    ring_topology = False
    kernelizable = True

    # dimensions the evolve-block annotation marks as mutable
    evolve_dims = ("backend", "completion", "placement", "ordering",
                   "granularity", "contexts", "issuer", "scope")

    def traits(self, hw=None):
        return dict(kernelizable=self.kernelizable,
                    ring_topology=self.ring_topology,
                    has_dcn=bool(hw and hw.has_dcn))

    def check(self, d: Directive, hw=None):
        return violations(d, **self.traits(hw))

    # --- to implement ---
    def example_inputs(self, key, mesh):
        raise NotImplementedError

    def reference(self, *inputs):
        raise NotImplementedError

    def host_baseline(self, mesh):
        raise NotImplementedError

    def build(self, directive: Directive, mesh):
        raise NotImplementedError

    def analytic_cost(self, directive: Directive, hw) -> float:
        raise NotImplementedError

    def cost_breakdown(self, directive: Directive, hw):
        """Ordered ``CostSegment`` decomposition of ``analytic_cost`` — the
        auditable form ``core/trace.py::schedule_timeline`` renders. The
        four shipped workloads implement this and derive ``analytic_cost``
        from ``CostBreakdown.total`` (so trace critical path == l3 scalar by
        construction); the base default wraps a directly-implemented
        ``analytic_cost`` in a single opaque segment so third-party
        workloads stay traceable without opting in."""
        from repro.core.cost_model import CostBreakdown, CostSegment
        return CostBreakdown(segments=(
            CostSegment("analytic_total", float(self.analytic_cost(directive, hw)),
                        "total"),))

    def default_tunables(self):
        return {}

    def fingerprint(self) -> str:
        """Stable identity of this workload *instance* (class name + scalar
        shape attributes) — the workload half of the warm-start eval-cache
        key (docs/search.md). Two instances with the same deployment shape
        fingerprint identically; a different shape (or workload) never
        reuses a cached score."""
        attrs = {k: v for k, v in vars(self).items()
                 if not k.startswith("_")
                 and isinstance(v, (int, float, str, bool))}
        body = ",".join(f"{k}={v}" for k, v in sorted(attrs.items()))
        return f"{self.name}|{body}"

    # --- the fault contract (core/faults.py, docs/kernels.md) ---
    def degrade(self, live_ranks):
        """Membership-aware reshape onto the surviving ranks: a **smaller
        workload of the same class** whose schedules, builders and l3
        model all run at ``n = len(live_ranks)`` (compaction renumbering,
        mirroring ``CollectiveSchedule.degrade``). ``fault_cost`` prices a
        dropped-peer plan through this; the fault suite runs the degraded
        build through the full cascade on the surviving mesh."""
        raise NotImplementedError(
            f"{self.name} has no degraded-mode reshape")

    def state_bytes_per_rank(self) -> int:
        """Resident bytes one rank holds at the deployment shape — the
        recovery term of ``fault_cost``: a dead rank's state must
        re-materialize over ICI before the degraded step can run, which
        keeps a smaller mesh from ever modeling *cheaper* than health."""
        raise NotImplementedError

    # --- the search contract (docs/kernels.md) ---
    def kernel_knobs(self, d: Directive) -> dict:
        """Directive → kernel-knob mapping, shared by ``build()`` and
        ``analytic_cost()`` so the two can never drift. The base default
        resolves every default tunable against the directive (raw values:
        consumers sanitize shape-dependent knobs at their own boundary via
        ``core/schedule.py::sanitize_tile``) plus the ``contexts``
        send-window depth. Overrides call ``super().kernel_knobs(d)`` and
        add their realization knobs."""
        k = {name: d.tunable(name, default)
             for name, default in self.default_tunables().items()}
        k["contexts"] = max(1, int(d.contexts))
        return k

    def collective_schedule(self, d: Directive):
        """The trace-time ``CollectiveSchedule`` the directive's build
        would issue, or ``None`` when the realization has no collective
        schedule at all (XLA backends, the kv solo tier) — then l0 static
        verification (``core/verify.py::verify_directive``) is vacuous.
        Overrides must return exactly the schedule the kernel iterates,
        built from the same ``kernel_knobs``, so the verifier and the
        kernel cannot drift."""
        del d
        return None
