from repro.workloads.base import Workload, WORKLOADS, get_workload
from repro.workloads import ring_attention, moe_dispatch, kv_transfer, \
    gemm_allgather, serving  # noqa: F401  (registration side effects)

__all__ = ["Workload", "WORKLOADS", "get_workload"]
