"""Workload 2: DeepSeek-V3 MoE dispatch/combine under skewed routing
(paper §4.3, Table 5, Figure 8).

Pipeline: (quantize) -> dispatch all-to-all -> expert GEMM1+SwiGLU+GEMM2 ->
combine all-to-all. Each rank owns one expert; routing is skewed (2:1..5:1)
so ranks are imbalanced.

Host baseline (the paper's "standard sequential flow"): padded equal-size
all-to-all on a single dependence chain — quantize, dispatch, compute,
combine, strictly sequential.

CUCo-discovered build (STREAM_SPLIT): the **self/remote split** — tokens
routed to the local expert never touch the network; their GEMM is issued with
no data dependence on the dispatch all-to-all, so dispatch hides behind
self-compute (paper Fig. 8: 3.04 ms local-chunk work covers ~1 ms dispatch).
int8 wire quantization is the paper's FP8-quantize phase, adapted.

Variable-size per-peer transfers (G=PER_PEER, `tight`): XLA's static-shape
collectives cannot express them on CPU (`ragged-all-to-all` is unimplemented
by the CPU thunk emitter) — the XLA-backend l2 path uses the padded
equivalent, while the l3 cost model credits the exact-size wire volume; on
real TPU the same builder switches to ``jax.lax.ragged_all_to_all``. This
mirrors the paper's own observation that host-level compilers cannot express
what the expert libraries do.

PALLAS_RDMA / HYBRID backends route to the fused device-initiated kernel
(repro.kernels.moe_dispatch — the DeepEP analogue): per-expert token blocks
remote-DMA'd directly into peer receive slabs at **tight per-peer sizes**
(`counts[e]` tokens per edge, not the padded max-capacity `C`), per-edge
SIGNAL completion semaphores, `contexts`-deep send windows, and the expert
GEMM for the earliest-arriving peer starting while later peers are in
flight (TILE_PIPELINED). A single kernel launch covers the whole
quantize/dispatch/compute/combine chain.

TILE_FUSED + COUNTER (the FLUX / CoCoNet point, Table 3) runs the expert
FFN as a tiled GEMM loop inside the same kernel: dispatch arrivals are
consumed one microblock at a time and each `combine_tile`-row output tile's
combine remote-DMA is issued the moment the tile is ready — per-tile
counter ticks instead of per-edge signals. Both kernelized points share
the `block_tokens`/`contexts`/`combine_tile` knobs the slow path refines;
``kernel_knobs`` (the ``Workload`` protocol's search contract) is the
single directive→knob mapping both build() and analytic_cost() consult.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.design_space import Directive
from repro.workloads.base import (BARRIER_OVERHEAD, KERNEL_LAUNCH,
                                  SIGNAL_OVERHEAD, TILE_SYNC, Workload,
                                  register)
from repro.compat import shard_map
from repro.core.cost_model import (CostBreakdown, CostSegment,
                                   per_tile_exposed_s, window_stall_factor)
from repro.kernels.moe_dispatch import make_schedule, quant_i8, swiglu_ffn


@register
class MoEDispatch(Workload):
    name = "moe_dispatch"
    ring_topology = False
    kernelizable = True           # repro.kernels.moe_dispatch (DeepEP-style)

    def __init__(self, n_dev=4, tokens_per_rank=4096, d=512, f=1024,
                 skew=3.0, axis="x", route_weights=None):
        self.n_dev = n_dev
        self.T = tokens_per_rank
        self.d = d
        self.f = f
        self.skew = skew
        self.axis = axis
        # explicit routing shares override the skew law — the degraded
        # (post-respill) instances carry their re-routed distribution here
        self.route_weights = None if route_weights is None \
            else tuple(float(v) for v in route_weights)

    # deterministic skewed routing: expert e's share ~ skew^(-e); identical
    # on every rank; tokens sorted into contiguous per-expert blocks.
    def _counts(self, T):
        if self.route_weights is not None:
            w = np.array(self.route_weights, dtype=float)
        else:
            w = np.array([self.skew ** (-e) for e in range(self.n_dev)])
        w = w / w.sum()
        counts = np.floor(w * T).astype(int)
        counts[0] += T - counts.sum()
        return counts

    # ------------------------------------------- fault contract (core/faults)
    def degrade(self, live_ranks, capacity_factor=1.25):
        """Dead experts' tokens respill across the survivors (the
        ``respill_counts`` capacity-factor rule applied to the deployment
        routing); the respilled counts become the degraded instance's
        routing shares so every ``T`` re-derives proportionally."""
        from repro.core.schedule import check_live, respill_counts
        live = check_live(live_ranks, self.n_dev)
        if len(live) == self.n_dev:
            return self
        new_counts = respill_counts(self._counts(self.T), live,
                                    capacity_factor)
        return type(self)(n_dev=len(live), tokens_per_rank=self.T, d=self.d,
                          f=self.f, skew=self.skew, axis=self.axis,
                          route_weights=new_counts)

    def state_bytes_per_rank(self):
        # resident activations + the rank's expert weights (f32)
        return 4 * (self.T * self.d
                    + self.d * 2 * self.f + self.f * self.d)

    def _assignment(self, T):
        return jnp.asarray(np.repeat(np.arange(self.n_dev), self._counts(T)),
                           jnp.int32)

    def example_inputs(self, key, mesh, T=None):
        T = T or min(self.T, 256)
        ks = jax.random.split(key, 3)
        x = jax.random.normal(ks[0], (self.n_dev, T, self.d), jnp.float32)
        w1 = jax.random.normal(ks[1], (self.n_dev, self.d, 2 * self.f),
                               jnp.float32) / math.sqrt(self.d)
        w2 = jax.random.normal(ks[2], (self.n_dev, self.f, self.d),
                               jnp.float32) / math.sqrt(self.f)
        return x, w1, w2

    def _ffn(self, x, w1, w2):
        return swiglu_ffn(x, w1, w2)

    def reference(self, x, w1, w2):
        n, T, d = x.shape
        assign = self._assignment(T)
        outs = []
        for r in range(n):
            o = jnp.zeros_like(x[r])
            for e in range(n):
                mask = (assign == e)[:, None]
                o = o + jnp.where(mask, self._ffn(x[r], w1[e], w2[e]), 0)
            outs.append(o)
        return jnp.stack(outs)

    # ------------------------------------------------------------- builders
    def _make(self, mesh, *, overlap, wire_i8):
        axis, n = self.axis, self.n_dev

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(axis), P(axis)),
                           out_specs=P(axis), check_vma=False)
        def run(x, w1, w2):
            x, w1, w2 = x[0], w1[0], w2[0]
            T, d = x.shape
            me = jax.lax.axis_index(axis)
            counts = self._counts(T)
            offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
            C = int(counts.max())
            cnt_arr = jnp.asarray(counts, jnp.int32)
            off_arr = jnp.asarray(offsets, jnp.int32)

            send = jnp.stack([
                jnp.pad(jax.lax.dynamic_slice_in_dim(
                    x, int(offsets[e]), int(counts[e])),
                    ((0, C - int(counts[e])), (0, 0)))
                for e in range(n)])                      # (n, C, d)

            def wire(t):
                if wire_i8:
                    q, s = quant_i8(t)
                    return (jax.lax.all_to_all(q, axis, 0, 0, tiled=True)
                            .astype(jnp.float32)
                            * jax.lax.all_to_all(s, axis, 0, 0, tiled=True))
                return jax.lax.all_to_all(t, axis, 0, 0, tiled=True)

            if overlap:
                # self/remote split: self-chunk FFN has no a2a dependence
                xp = jnp.pad(x, ((0, C), (0, 0)))
                self_blk = jax.lax.dynamic_slice(xp, (off_arr[me], 0), (C, d))
                h_self = self._ffn(self_blk, w1, w2)      # overlaps dispatch
                got = wire(send)                          # (n, C, d)
                got = jnp.where((jnp.arange(n) != me)[:, None, None], got, 0.0)
            else:
                got = wire(send)                          # sequential chain

            h = self._ffn(got.reshape(n * C, d), w1, w2).reshape(n, C, d)
            back = jax.lax.all_to_all(h, axis, 0, 0, tiled=True)  # combine

            y = jnp.zeros_like(x)
            for e in range(n):                            # unpack padded blocks
                blk = back[e, :int(counts[e])]
                y = jax.lax.dynamic_update_slice_in_dim(
                    y, blk, int(offsets[e]), axis=0)
            if overlap:                                   # merge self chunk
                yp = jnp.pad(y, ((0, C), (0, 0)))
                cur = jax.lax.dynamic_slice(yp, (off_arr[me], 0), (C, d))
                valid = (jnp.arange(C) < cnt_arr[me])[:, None]
                yp = jax.lax.dynamic_update_slice(
                    yp, jnp.where(valid, h_self, cur), (off_arr[me], 0))
                y = yp[:T]
            return y[None]

        return run

    def host_baseline(self, mesh):
        return self._make(mesh, overlap=False, wire_i8=False)

    # directive -> kernel-knob mapping shared by build() and analytic_cost()
    # (the Workload.kernel_knobs search contract, docs/kernels.md)
    def kernel_knobs(self, d: Directive):
        k = super().kernel_knobs(d)      # tunables (raw) + contexts
        B = max(1, int(k["block_tokens"]))
        k.update(
            block_tokens=B,
            # PER_TILE (the FLUX coordinate) quantizes to microblocks too —
            # both per-peer and per-tile edges carry exact token counts
            tight=(d.granularity in ("PER_PEER", "PER_TILE")
                   and bool(k["tight"])),
            # BARRIER forces the global-rendezvous shape even under a
            # TILE_FUSED placement; COUNTER/SIGNAL fuse the combine loop
            tile_fused=(d.placement == "TILE_FUSED"
                        and d.completion != "BARRIER"),
            # combine_tile stays raw (default: one tile per microblock) —
            # the sharded kernel entry and the schedule's combine_ticks
            # each sanitize at their own boundary
            combine_tile=d.tunable("combine_tile", B),
            pipelined=d.placement in ("TILE_FUSED", "TILE_PIPELINED",
                                      "STREAM_SPLIT"),
            barrier=d.completion == "BARRIER")
        return k

    def collective_schedule(self, d: Directive):
        # the exact schedule _make_kernel hands the Pallas kernel at the
        # deployment token count — l0 (core/verify.py) lowers and checks
        # it before any build is attempted
        if d.backend not in ("PALLAS_RDMA", "HYBRID"):
            return None
        k = self.kernel_knobs(d)
        return make_schedule(self._counts(self.T), k["block_tokens"],
                             k["tight"])

    def _make_kernel(self, mesh, d: Directive):
        from repro.kernels.moe_dispatch import moe_dispatch_combine
        k = self.kernel_knobs(d)

        def run(x, w1, w2):
            return moe_dispatch_combine(
                x, w1, w2, mesh, axis=self.axis,
                counts=self._counts(x.shape[1]),
                block_tokens=k["block_tokens"], tight=k["tight"],
                pipelined=k["pipelined"], barrier=k["barrier"],
                tile_fused=k["tile_fused"], combine_tile=k["combine_tile"],
                contexts=k["contexts"], wire_i8=bool(k["wire_i8"]))

        return run

    def build(self, d: Directive, mesh):
        if d.backend in ("PALLAS_RDMA", "HYBRID"):
            return self._make_kernel(mesh, d)
        return self._make(mesh, overlap=(d.placement == "STREAM_SPLIT"),
                          wire_i8=bool(d.tunable("wire_i8", 0)))

    def default_tunables(self):
        return {"tight": 1, "wire_i8": 0, "block_tokens": 64,
                "combine_tile": 64}

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        return self.cost_breakdown(d, hw).total

    def cost_breakdown(self, d: Directive, hw) -> CostBreakdown:
        Seg = CostSegment
        n, T, dm, f = self.n_dev, self.T, self.d, self.f
        counts = self._counts(T)
        C = int(counts.max())
        kernel = d.backend in ("PALLAS_RDMA", "HYBRID")
        k = self.kernel_knobs(d) if kernel else None
        tight = k["tight"] if kernel \
            else bool(d.granularity == "PER_PEER" and d.tunable("tight", 1))
        wire_i8 = bool(d.tunable("wire_i8", 0))
        bytes_per = 1 if wire_i8 else 2
        # the busiest expert rank (rank 0 under skew) bounds the step
        recv_tokens = int(counts[0]) * n if tight else C * n
        self_tokens = int(counts[0])
        flops = 3 * 2 * recv_tokens * dm * f          # GEMM1 (2f) + GEMM2
        t_comp = flops / hw.chip.peak_bf16_flops
        t_self = t_comp * self_tokens / max(1, recv_tokens)
        t_remote = t_comp - t_self
        # tight wire: exactly the off-rank tokens (counts.sum() - counts[0]);
        # padded wire: the max-capacity block to every peer (C * (n - 1))
        sent = (counts.sum() - counts[0]) if tight else C * (n - 1)
        t_disp = sent * dm * bytes_per / hw.chip.ici_link_bw
        t_comb = sent * dm * 2 / hw.chip.ici_link_bw  # combine in bf16
        t_quant = (2 * T * dm * 2 / hw.chip.hbm_bw) if wire_i8 else 0.0

        if kernel:
            # fused device-initiated kernel: one launch for the whole
            # quantize/dispatch/compute/combine chain; per-edge signal
            # semaphores instead of a global barrier; per-round DMA
            # issue/check overhead for the permutation schedule. The l3
            # target is real TPU hardware, where the interpreter's lockstep
            # dummy rounds are elided — charge the tighter executed
            # schedule, never the padded one.
            B = k["block_tokens"]
            sched = make_schedule(counts, B, k["tight"])
            disp_rounds = sched.issued_rounds(elide_dummy=True)
            # combine rounds are rank-dependent: the busiest expert (rank
            # 0) returns blocks[0] microblocks to every source
            ticks = sched.combine_ticks(k["combine_tile"], rank=0,
                                        elide_dummy=True) \
                if k["tile_fused"] \
                else sched.combine_issued_rounds(0, elide_dummy=True)
            if k["tile_fused"]:
                sync = 0.0       # readiness IS the per-tile ticks below
                # (SIGNAL and COUNTER build the identical fused kernel)
            elif d.completion == "BARRIER":
                sync = BARRIER_OVERHEAD
            else:
                sync = SIGNAL_OVERHEAD * max(1, n - 1)
            tail = (
                Seg("quant", t_quant, "quant"),
                Seg("sync", sync, "sync"),
                Seg("launch", KERNEL_LAUNCH, "launch"),
                Seg("tile_sync", (disp_rounds + ticks) * TILE_SYNC, "sync",
                    meta={"issued_rounds": disp_rounds, "ticks": ticks}),
            )
            if k["tile_fused"]:
                # FLUX credit: expert compute starts once the first
                # microblock lands, and the combine write of tile t hides
                # behind the GEMM of tile t+1 — only the final tile's
                # transfer stays exposed (per_tile_exposed_s), scaled by
                # the send-window recycle stall: a contexts-deep window
                # leaves ~1/contexts of a tile's wire unhidden while the
                # oldest send drains before the next tile may issue.
                startup = t_disp / max(1, disp_rounds)
                span = max(t_disp, startup + t_comp)
                window = window_stall_factor(k["contexts"])
                return CostBreakdown(segments=(
                    Seg("fused_span", span, "overlap",
                        meta={"wire_s": t_disp,
                              "compute_s": startup + t_comp}),
                    Seg("window_stall", window * per_tile_exposed_s(
                        sent * dm * 2, hw.chip.ici_link_bw, ticks), "stall",
                        meta={"contexts": k["contexts"]}),
                ) + tail, schedule=sched, knobs=k,
                    meta={"path": "kernel_tile_fused"})
            pipelined = (d.placement in ("TILE_PIPELINED", "STREAM_SPLIT")
                         and d.completion != "BARRIER" and d.contexts >= 2)
            if pipelined:
                # self-edge compute hides dispatch; per-peer compute hides
                # later arrivals; combine of peer p hides behind compute of
                # p+1 — only the last peer's chunks stay exposed.
                peers = max(1, n - 1)
                span = max(t_disp, t_self + t_remote * (peers - 1) / peers)
                return CostBreakdown(segments=(
                    Seg("pipeline_span", span, "overlap",
                        meta={"wire_s": t_disp,
                              "compute_s": t_self
                              + t_remote * (peers - 1) / peers}),
                    Seg("last_peer_compute", t_remote / peers, "compute"),
                    Seg("last_peer_combine", t_comb / peers, "wire"),
                ) + tail, schedule=sched, knobs=k,
                    meta={"path": "kernel_pipelined"})
            return CostBreakdown(segments=(
                Seg("dispatch", t_disp, "wire"),
                Seg("expert_ffn", t_comp, "compute"),
                Seg("combine", t_comb, "wire"),
            ) + tail, schedule=sched, knobs=k, meta={"path": "kernel_plain"})

        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        launches = KERNEL_LAUNCH * 4                  # quant/disp/comp/comb
        if d.placement == "STREAM_SPLIT":
            stage1 = max(t_disp + t_quant, t_self)    # dispatch hidden
            return CostBreakdown(segments=(
                Seg("dispatch_overlap", stage1, "overlap",
                    meta={"wire_s": t_disp + t_quant, "compute_s": t_self}),
                Seg("remote_ffn", t_remote, "compute"),
                Seg("combine", t_comb, "wire"),
                Seg("sync", sync, "sync"),
                Seg("launch", launches, "launch"),
            ), meta={"path": "xla_stream_split"})
        return CostBreakdown(segments=(
            Seg("quant", t_quant, "quant"),
            Seg("dispatch", t_disp, "wire"),
            Seg("expert_ffn", t_comp, "compute"),
            Seg("combine", t_comb, "wire"),
            Seg("sync", sync, "sync"),
            Seg("launch", launches, "launch"),
        ), meta={"path": "xla_host"})
