"""Workload 2: DeepSeek-V3 MoE dispatch/combine under skewed routing
(paper §4.3, Table 5, Figure 8).

Pipeline: (quantize) -> dispatch all-to-all -> expert GEMM1+SwiGLU+GEMM2 ->
combine all-to-all. Each rank owns one expert; routing is skewed (2:1..5:1)
so ranks are imbalanced.

Host baseline (the paper's "standard sequential flow"): padded equal-size
all-to-all on a single dependence chain — quantize, dispatch, compute,
combine, strictly sequential.

CUCo-discovered build (STREAM_SPLIT): the **self/remote split** — tokens
routed to the local expert never touch the network; their GEMM is issued with
no data dependence on the dispatch all-to-all, so dispatch hides behind
self-compute (paper Fig. 8: 3.04 ms local-chunk work covers ~1 ms dispatch).
int8 wire quantization is the paper's FP8-quantize phase, adapted.

Variable-size per-peer transfers (G=PER_PEER, `tight`): XLA's static-shape
collectives cannot express them on CPU (`ragged-all-to-all` is unimplemented
by the CPU thunk emitter) — the executable l2 path uses the padded
equivalent, while the l3 cost model credits the exact-size wire volume; on
real TPU the same builder switches to ``jax.lax.ragged_all_to_all``. This
mirrors the paper's own observation that host-level compilers cannot express
what the expert libraries do.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.design_space import Directive
from repro.workloads.base import (BARRIER_OVERHEAD, KERNEL_LAUNCH,
                                  SIGNAL_OVERHEAD, Workload, register)


def _quant_i8(x):
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s


@register
class MoEDispatch(Workload):
    name = "moe_dispatch"
    ring_topology = False
    kernelizable = False          # the paper's MoE win is schedule-level

    def __init__(self, n_dev=4, tokens_per_rank=4096, d=512, f=1024,
                 skew=3.0, axis="x"):
        self.n_dev = n_dev
        self.T = tokens_per_rank
        self.d = d
        self.f = f
        self.skew = skew
        self.axis = axis

    # deterministic skewed routing: expert e's share ~ skew^(-e); identical
    # on every rank; tokens sorted into contiguous per-expert blocks.
    def _counts(self, T):
        w = np.array([self.skew ** (-e) for e in range(self.n_dev)])
        w = w / w.sum()
        counts = np.floor(w * T).astype(int)
        counts[0] += T - counts.sum()
        return counts

    def _assignment(self, T):
        return jnp.asarray(np.repeat(np.arange(self.n_dev), self._counts(T)),
                           jnp.int32)

    def example_inputs(self, key, mesh, T=None):
        T = T or min(self.T, 256)
        ks = jax.random.split(key, 3)
        x = jax.random.normal(ks[0], (self.n_dev, T, self.d), jnp.float32)
        w1 = jax.random.normal(ks[1], (self.n_dev, self.d, 2 * self.f),
                               jnp.float32) / math.sqrt(self.d)
        w2 = jax.random.normal(ks[2], (self.n_dev, self.f, self.d),
                               jnp.float32) / math.sqrt(self.f)
        return x, w1, w2

    def _ffn(self, x, w1, w2):
        g, u = jnp.split(x @ w1, 2, axis=-1)
        return (jax.nn.silu(g) * u) @ w2

    def reference(self, x, w1, w2):
        n, T, d = x.shape
        assign = self._assignment(T)
        outs = []
        for r in range(n):
            o = jnp.zeros_like(x[r])
            for e in range(n):
                mask = (assign == e)[:, None]
                o = o + jnp.where(mask, self._ffn(x[r], w1[e], w2[e]), 0)
            outs.append(o)
        return jnp.stack(outs)

    # ------------------------------------------------------------- builders
    def _make(self, mesh, *, overlap, wire_i8):
        axis, n = self.axis, self.n_dev

        @functools.partial(jax.shard_map, mesh=mesh,
                           in_specs=(P(axis), P(axis), P(axis)),
                           out_specs=P(axis), check_vma=False)
        def run(x, w1, w2):
            x, w1, w2 = x[0], w1[0], w2[0]
            T, d = x.shape
            me = jax.lax.axis_index(axis)
            counts = self._counts(T)
            offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
            C = int(counts.max())
            cnt_arr = jnp.asarray(counts, jnp.int32)
            off_arr = jnp.asarray(offsets, jnp.int32)

            send = jnp.stack([
                jnp.pad(jax.lax.dynamic_slice_in_dim(
                    x, int(offsets[e]), int(counts[e])),
                    ((0, C - int(counts[e])), (0, 0)))
                for e in range(n)])                      # (n, C, d)

            def wire(t):
                if wire_i8:
                    q, s = _quant_i8(t)
                    return (jax.lax.all_to_all(q, axis, 0, 0, tiled=True)
                            .astype(jnp.float32)
                            * jax.lax.all_to_all(s, axis, 0, 0, tiled=True))
                return jax.lax.all_to_all(t, axis, 0, 0, tiled=True)

            if overlap:
                # self/remote split: self-chunk FFN has no a2a dependence
                xp = jnp.pad(x, ((0, C), (0, 0)))
                self_blk = jax.lax.dynamic_slice(xp, (off_arr[me], 0), (C, d))
                h_self = self._ffn(self_blk, w1, w2)      # overlaps dispatch
                got = wire(send)                          # (n, C, d)
                got = jnp.where((jnp.arange(n) != me)[:, None, None], got, 0.0)
            else:
                got = wire(send)                          # sequential chain

            h = self._ffn(got.reshape(n * C, d), w1, w2).reshape(n, C, d)
            back = jax.lax.all_to_all(h, axis, 0, 0, tiled=True)  # combine

            y = jnp.zeros_like(x)
            for e in range(n):                            # unpack padded blocks
                blk = back[e, :int(counts[e])]
                y = jax.lax.dynamic_update_slice_in_dim(
                    y, blk, int(offsets[e]), axis=0)
            if overlap:                                   # merge self chunk
                yp = jnp.pad(y, ((0, C), (0, 0)))
                cur = jax.lax.dynamic_slice(yp, (off_arr[me], 0), (C, d))
                valid = (jnp.arange(C) < cnt_arr[me])[:, None]
                yp = jax.lax.dynamic_update_slice(
                    yp, jnp.where(valid, h_self, cur), (off_arr[me], 0))
                y = yp[:T]
            return y[None]

        return run

    def host_baseline(self, mesh):
        return self._make(mesh, overlap=False, wire_i8=False)

    def build(self, d: Directive, mesh):
        return self._make(mesh, overlap=(d.placement == "STREAM_SPLIT"),
                          wire_i8=bool(d.tunable("wire_i8", 0)))

    def default_tunables(self):
        return {"tight": 1, "wire_i8": 0}

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        n, T, dm, f = self.n_dev, self.T, self.d, self.f
        counts = self._counts(T)
        C = int(counts.max())
        tight = bool(d.granularity == "PER_PEER" and d.tunable("tight", 1))
        wire_i8 = bool(d.tunable("wire_i8", 0))
        bytes_per = 1 if wire_i8 else 2
        # the busiest expert rank (rank 0 under skew) bounds the step
        recv_tokens = int(counts[0]) * n if tight else C * n
        self_tokens = int(counts[0])
        flops = 3 * 2 * recv_tokens * dm * f          # GEMM1 (2f) + GEMM2
        t_comp = flops / hw.chip.peak_bf16_flops
        t_self = t_comp * self_tokens / max(1, recv_tokens)
        t_remote = t_comp - t_self
        sent = (counts.sum() - counts[0]) if tight else C * (n - 1)
        t_disp = sent * dm * bytes_per / hw.chip.ici_link_bw
        t_comb = sent * dm * 2 / hw.chip.ici_link_bw  # combine in bf16
        t_quant = (2 * T * dm * 2 / hw.chip.hbm_bw) if wire_i8 else 0.0
        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        launches = KERNEL_LAUNCH * 4                  # quant/disp/comp/comb
        if d.placement == "STREAM_SPLIT":
            stage1 = max(t_disp + t_quant, t_self)    # dispatch hidden
            return stage1 + t_remote + t_comb + sync + launches
        return t_quant + t_disp + t_comp + t_comb + sync + launches
