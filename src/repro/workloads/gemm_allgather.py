"""Workload 4: GEMM + AllGather (paper Appendix M; the minimal post-compute
collective).

Host baseline: local GEMM, then an XLA all-gather of the full output —
sequential by data dependence.

Device-initiated builds: repro.kernels.gemm_allgather — the result tile is
broadcast to peers by remote DMA as soon as it is computed (TILE_FUSED,
G=PER_TILE), or per-peer slabs after the full GEMM (DEFERRED). The XLA
STREAM_SPLIT build chunks the GEMM and all-gathers chunk c while chunk c+1
computes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.design_space import Directive
from repro.kernels.gemm_allgather import gemm_allgather as ga_kernel
from repro.workloads.base import (BARRIER_OVERHEAD, KERNEL_LAUNCH,
                                  SIGNAL_OVERHEAD, TILE_SYNC, Workload,
                                  register)
from repro.compat import shard_map


@register
class GemmAllGather(Workload):
    name = "gemm_allgather"
    ring_topology = False
    kernelizable = True

    def __init__(self, n_dev=4, M=4096, K=4096, N=4096, axis="x"):
        self.n_dev = n_dev
        self.M = M
        self.K = K
        self.N = N
        self.axis = axis

    def example_inputs(self, key, mesh, M_l=None):
        M_l = M_l or 128
        K, N = min(self.K, 128), min(self.N, 128)
        ks = jax.random.split(key, 2)
        a = jax.random.normal(ks[0], (self.n_dev, M_l, K), jnp.float32)
        b = jax.random.normal(ks[1], (K, N), jnp.float32)
        return a, b

    def reference(self, a, b):
        from repro.kernels.ref import gemm_allgather_ref
        return gemm_allgather_ref(a, b)

    # ------------------------------------------------------------- builders
    def host_baseline(self, mesh):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None)),
                           out_specs=P(axis), check_vma=False)
        def run(a, b):
            c = a[0] @ b
            return jax.lax.all_gather(c, axis, tiled=True)[None]

        return run

    def _stream_split(self, mesh, chunks):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None)),
                           out_specs=P(axis), check_vma=False)
        def run(a, b):
            a = a[0]
            M_l = a.shape[0]
            cs = max(1, M_l // chunks)
            outs = []
            for c0 in range(0, M_l, cs):
                c = a[c0:c0 + cs] @ b            # chunk c+1's GEMM is
                outs.append(jax.lax.all_gather(c, axis, tiled=False))
            # (n, cs, N) chunks -> (n*M_l, N)
            full = jnp.concatenate(outs, axis=1)
            return full.reshape(-1, b.shape[1])[None]

        return run

    def build(self, d: Directive, mesh):
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                return self._stream_split(mesh, int(d.tunable("chunks", 4)))
            return self.host_baseline(mesh)
        fused = d.placement in ("TILE_FUSED", "TILE_PIPELINED")
        tile_m = int(d.tunable("tile_m", 128))

        def run(a, b):
            return ga_kernel(a, b, mesh, axis=self.axis, tile_m=tile_m,
                             fused=fused)

        return run

    def default_tunables(self):
        return {"tile_m": 128, "chunks": 4}

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        n = self.n_dev
        M_l = self.M // n
        t_gemm = 2.0 * M_l * self.K * self.N / hw.chip.peak_bf16_flops
        wire = (n - 1) * M_l * self.N * 2            # my slab to n-1 peers
        t_wire = wire / hw.chip.ici_link_bw
        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                chunks = max(1, int(d.tunable("chunks", 4)))
                per = t_gemm / chunks
                pw = t_wire / chunks
                # chunk c's gather overlaps chunk c+1's GEMM
                return per + max((chunks - 1) * per, (chunks - 1) * pw) + pw \
                    + sync + KERNEL_LAUNCH * 2
            return t_gemm + t_wire + sync + KERNEL_LAUNCH * 2
        if d.placement in ("TILE_FUSED", "TILE_PIPELINED"):
            tiles = max(1, M_l // max(1, int(d.tunable("tile_m", 128))))
            per = t_gemm / tiles
            pw = t_wire / tiles
            return per + max((tiles - 1) * per, (tiles - 1) * pw) + pw \
                + tiles * TILE_SYNC + sync + KERNEL_LAUNCH
        return t_gemm + t_wire + sync + KERNEL_LAUNCH
