"""Workload 4: GEMM + AllGather (paper Appendix M; the minimal post-compute
collective).

Host baseline: local GEMM, then an XLA all-gather of the full output —
sequential by data dependence.

Device-initiated builds: repro.kernels.gemm_allgather — the second fully
kernelized workload (after moe_dispatch). TILE_FUSED broadcasts each result
tile by remote DMA the moment its GEMM finishes (G=PER_TILE; with COUNTER
completion the receive side ticks arrivals off one tile at a time — the
FLUX point); DEFERRED ships one whole slab per peer after the full GEMM.
Both run the same trace-time ``BroadcastSchedule`` under a ``contexts``-deep
send window. The XLA STREAM_SPLIT build chunks the GEMM and all-gathers
chunk c while chunk c+1 computes.

``kernel_knobs`` (the ``Workload`` protocol's search contract) is the
single directive→knob mapping both ``build()`` and ``analytic_cost()``
consult (docs/kernels.md); the ``tile_m`` tunable is drawn from the central
``TUNABLES`` grid and sanitized to a divisor of the local slab at each
shape boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.cost_model import (CostBreakdown, CostSegment,
                                   per_tile_exposed_s, window_stall_factor)
from repro.core.design_space import Directive
from repro.kernels.gemm_allgather import (gemm_allgather as ga_kernel,
                                          make_broadcast_schedule,
                                          sanitize_tile_m)
from repro.workloads.base import (BARRIER_OVERHEAD, KERNEL_LAUNCH,
                                  SIGNAL_OVERHEAD, TILE_SYNC, Workload,
                                  register)
from repro.compat import shard_map


@register
class GemmAllGather(Workload):
    name = "gemm_allgather"
    ring_topology = False
    kernelizable = True

    def __init__(self, n_dev=4, M=4096, K=4096, N=4096, axis="x"):
        self.n_dev = n_dev
        self.M = M
        self.K = K
        self.N = N
        self.axis = axis

    def example_inputs(self, key, mesh, M_l=None):
        M_l = M_l or 128
        K, N = min(self.K, 128), min(self.N, 128)
        ks = jax.random.split(key, 2)
        a = jax.random.normal(ks[0], (self.n_dev, M_l, K), jnp.float32)
        b = jax.random.normal(ks[1], (K, N), jnp.float32)
        return a, b

    def reference(self, a, b):
        from repro.kernels.ref import gemm_allgather_ref
        return gemm_allgather_ref(a, b)

    # ------------------------------------------- fault contract (core/faults)
    def degrade(self, live_ranks):
        """The global GEMM redistributes over the survivors: the local slab
        grows to ``ceil(M / n')`` rows (M rounds up to the new rank count —
        the broadcast schedule requires equal slabs)."""
        from repro.core.schedule import check_live
        live = check_live(live_ranks, self.n_dev)
        if len(live) == self.n_dev:
            return self
        n = len(live)
        M_l = -(-self.M // n)
        return type(self)(n_dev=n, M=M_l * n, K=self.K, N=self.N,
                          axis=self.axis)

    def state_bytes_per_rank(self):
        # resident A slab + result slab (f32); B is replicated — survivors
        # already hold it, so a dead rank's copy needs no recovery wire
        M_l = self.M // self.n_dev
        return 4 * M_l * (self.K + self.N)

    # ------------------------------------------------------------- builders
    def host_baseline(self, mesh):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None)),
                           out_specs=P(axis), check_vma=False)
        def run(a, b):
            c = a[0] @ b
            return jax.lax.all_gather(c, axis, tiled=True)[None]

        return run

    def _stream_split(self, mesh, chunks):
        axis = self.axis

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(None, None)),
                           out_specs=P(axis), check_vma=False)
        def run(a, b):
            a = a[0]
            M_l = a.shape[0]
            cs = max(1, M_l // chunks)
            outs = []
            for c0 in range(0, M_l, cs):
                c = a[c0:c0 + cs] @ b            # chunk c+1's GEMM is
                outs.append(jax.lax.all_gather(c, axis, tiled=False))
            # (n, cs, N) chunks -> (n*M_l, N)
            full = jnp.concatenate(outs, axis=1)
            return full.reshape(-1, b.shape[1])[None]

        return run

    # directive -> kernel-knob mapping shared by build() and analytic_cost()
    # (the Workload.kernel_knobs search contract, docs/kernels.md)
    def kernel_knobs(self, d: Directive, M_l=None):
        k = super().kernel_knobs(d)      # tunables (raw) + contexts
        if M_l is None:
            M_l = self.M // self.n_dev   # the deployment slab (l3 model)
        k.update(
            # the TUNABLES grid need not divide a given local slab — the
            # kernel contract requires an exact divisor, so sanitize here
            # (a slow-path diff patch must never crash the evaluator)
            tile_m=sanitize_tile_m(k["tile_m"], M_l),
            # BARRIER forces the deferred whole-slab drain even under a
            # TILE_FUSED placement (mirrors moe_dispatch.kernel_knobs)
            fused=(d.placement in ("TILE_FUSED", "TILE_PIPELINED")
                   and d.completion != "BARRIER"),
            # COUNTER = per-tile arrival ticks (the FLUX point); SIGNAL
            # keeps per-tile issue but waits once per inbound edge
            counter=d.completion == "COUNTER")
        return k

    def collective_schedule(self, d: Directive):
        # the deployment-slab broadcast schedule the kernel iterates —
        # l0 (core/verify.py) statically checks it ahead of l1 build
        if d.backend == "XLA_COLLECTIVE":
            return None
        k = self.kernel_knobs(d)
        return make_broadcast_schedule(self.n_dev, self.M // self.n_dev,
                                       k["tile_m"], k["fused"])

    def build(self, d: Directive, mesh):
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                return self._stream_split(mesh, int(d.tunable("chunks", 4)))
            return self.host_baseline(mesh)

        def run(a, b):
            k = self.kernel_knobs(d, a.shape[1])
            return ga_kernel(a, b, mesh, axis=self.axis, tile_m=k["tile_m"],
                             fused=k["fused"], counter=k["counter"],
                             contexts=k["contexts"])

        return run

    def default_tunables(self):
        return {"tile_m": 128, "chunks": 4}

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        return self.cost_breakdown(d, hw).total

    def cost_breakdown(self, d: Directive, hw) -> CostBreakdown:
        Seg = CostSegment
        n = self.n_dev
        M_l = self.M // n
        t_gemm = 2.0 * M_l * self.K * self.N / hw.chip.peak_bf16_flops
        wire = (n - 1) * M_l * self.N * 2            # my slab to n-1 peers
        t_wire = wire / hw.chip.ici_link_bw
        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                chunks = max(1, int(d.tunable("chunks", 4)))
                per = t_gemm / chunks
                pw = t_wire / chunks
                # chunk c's gather overlaps chunk c+1's GEMM
                return CostBreakdown(segments=(
                    Seg("gemm_chunk0", per, "compute"),
                    Seg("gather_overlap",
                        max((chunks - 1) * per, (chunks - 1) * pw), "overlap",
                        meta={"compute_s": (chunks - 1) * per,
                              "wire_s": (chunks - 1) * pw, "chunks": chunks}),
                    Seg("gather_tail", pw, "wire"),
                    Seg("sync", sync, "sync"),
                    Seg("launch", KERNEL_LAUNCH * 2, "launch"),
                ), meta={"path": "xla_stream_split"})
            return CostBreakdown(segments=(
                Seg("gemm", t_gemm, "compute"),
                Seg("all_gather", t_wire, "wire"),
                Seg("sync", sync, "sync"),
                Seg("launch", KERNEL_LAUNCH * 2, "launch"),
            ), meta={"path": "xla_deferred"})

        # kernelized (PALLAS_RDMA / HYBRID): one fused launch; the schedule
        # charges TILE_SYNC per issued broadcast round and per completion
        # tick — same accounting shape as the moe_dispatch kernel model.
        k = self.kernel_knobs(d, M_l)
        sched = make_broadcast_schedule(n, M_l, k["tile_m"], k["fused"])
        ticks = sched.completion_ticks(k["counter"])
        if d.completion == "BARRIER":
            sync = BARRIER_OVERHEAD
        elif k["counter"]:
            sync = 0.0        # readiness IS the per-tile ticks below
        else:
            sync = SIGNAL_OVERHEAD * max(1, n - 1)
        tail = (
            Seg("sync", sync, "sync"),
            Seg("launch", KERNEL_LAUNCH, "launch"),
            Seg("tile_sync", (sched.issued_rounds() + ticks) * TILE_SYNC,
                "sync", meta={"issued_rounds": sched.issued_rounds(),
                              "ticks": ticks}),
        )
        if k["fused"]:
            # FLUX credit: tile t's broadcast hides behind tile t+1's GEMM
            # — only the final tile's transfer stays exposed
            # (per_tile_exposed_s over the per-tile issue granularity),
            # scaled by the send-window recycle stall: a contexts-deep
            # window leaves ~1/contexts of a tile's wire unhidden while
            # the oldest send drains before the next round may issue.
            per_gemm = t_gemm / max(1, sched.nt)
            span = max(t_gemm, per_gemm + t_wire)
            window = window_stall_factor(k["contexts"])
            return CostBreakdown(segments=(
                Seg("fused_span", span, "overlap",
                    meta={"compute_s": t_gemm, "wire_s": per_gemm + t_wire}),
                Seg("window_stall", window * per_tile_exposed_s(
                    wire, hw.chip.ici_link_bw, sched.issued_rounds()),
                    "stall", meta={"contexts": k["contexts"]}),
            ) + tail, schedule=sched, knobs=k, meta={"path": "kernel_fused"})
        # DEFERRED slab path: comm strictly after compute; the window
        # pipelines the per-peer slabs on the wire but the serial
        # dependence on the full GEMM remains.
        return CostBreakdown(segments=(
            Seg("gemm", t_gemm, "compute"),
            Seg("slab_broadcast", t_wire, "wire"),
        ) + tail, schedule=sched, knobs=k, meta={"path": "kernel_deferred"})
