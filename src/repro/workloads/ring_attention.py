"""Workload 1: Flash Attention with Context Parallelism (ring attention).

Host-driven baseline: one attention round per held KV shard, with an XLA
``ppermute`` between rounds — each round's compute depends on the permute
result, forcing strictly sequential execution (the paper's Figure 7 host
timeline: exchange / compute / exchange / …).

Device-initiated builds rotate KV *inside* a Pallas kernel via remote DMA
(repro.kernels.ring_attention), realized against the shared
``core/schedule.py::RingSchedule``: DEFERRED rotates whole shards and
fences eagerly, TILE_PIPELINED overlaps the rotation with the round's
compute (lazy fence), and TILE_FUSED + COUNTER (the FLUX point for rings)
rotates ``kv_chunk``-row chunks under a ``contexts``-deep send window with
per-chunk arrival ticks — chunk c's attention computes while chunk c+1 is
still in flight. An XLA STREAM_SPLIT build double-buffers the permute at
graph level so XLA's async collective scheduler can overlap it with the
round's compute.

``kernel_knobs`` (the ``Workload`` protocol's search contract) is the
single directive→knob mapping both ``build()`` and ``analytic_cost()``
consult; ``kv_chunk`` is drawn from the central ``TUNABLES`` grid and
sanitized to a divisor of the local KV shard at each shape boundary.

Full deployment shape (paper §4.2): 4 devices, SEQ in {4096, 8192},
HD in {32, 64}, GPT-2-ish multi-head layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.cost_model import (CostBreakdown, CostSegment,
                                   per_tile_exposed_s, window_stall_factor)
from repro.core.design_space import Directive
from repro.core.schedule import make_ring_schedule
from repro.kernels.ref import ring_attention_ref
from repro.kernels.ring_attention import ring_attention as ring_kernel
from repro.workloads.base import (BARRIER_OVERHEAD, KERNEL_LAUNCH,
                                  SIGNAL_OVERHEAD, TILE_SYNC, Workload,
                                  register)
from repro.compat import shard_map


@register
class RingAttention(Workload):
    name = "ring_attention"
    ring_topology = True
    kernelizable = True

    def __init__(self, n_dev=4, BH=8, seq=4096, hd=64, axis="x"):
        self.n_dev = n_dev
        self.BH = BH
        self.seq = seq
        self.hd = hd
        self.sl = seq // n_dev
        self.axis = axis

    def example_inputs(self, key, mesh, sl=None):
        sl = sl or min(self.sl, 128)
        ks = jax.random.split(key, 3)
        shape = (self.n_dev, self.BH, sl, self.hd)
        return tuple(jax.random.normal(k, shape, jnp.float32) for k in ks)

    def reference(self, q, k, v):
        return ring_attention_ref(q, k, v, causal=True)

    # ------------------------------------------- fault contract (core/faults)
    def degrade(self, live_ranks):
        """The global sequence re-shards over the survivors: the local KV
        shard grows to ``ceil(seq / n')`` rows (seq rounds up to the new
        rank count — the rotation requires equal shards)."""
        from repro.core.schedule import check_live
        live = check_live(live_ranks, self.n_dev)
        if len(live) == self.n_dev:
            return self
        n = len(live)
        sl = -(-self.seq // n)
        return type(self)(n_dev=n, BH=self.BH, seq=sl * n, hd=self.hd,
                          axis=self.axis)

    def state_bytes_per_rank(self):
        # resident Q/K/V shards (f32)
        return 4 * 3 * self.BH * self.sl * self.hd

    # ------------------------------------------------------------- builders
    def host_baseline(self, mesh):
        """Sequential rounds with an XLA collective-permute between them."""
        axis, n = self.axis, self.n_dev

        @functools.partial(shard_map, mesh=mesh, in_specs=P(axis),
                           out_specs=P(axis), check_vma=False)
        def run(q, k, v):
            q, k, v = q[0], k[0], v[0]
            me = jax.lax.axis_index(axis)
            sl = q.shape[1]
            perm = [(i, (i + 1) % n) for i in range(n)]
            qpos = me * sl + jnp.arange(sl)

            def round_fn(carry, r):
                k_c, v_c, m, l, acc = carry
                src = (me - r) % n
                kpos = src * sl + jnp.arange(sl)
                s = jnp.einsum("bqd,bkd->bqk", q, k_c) / math.sqrt(self.hd)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, -1e30)
                m_new = jnp.maximum(m, jnp.max(s, -1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l = l * alpha + jnp.sum(p, -1)
                acc = acc * alpha[..., None] + jnp.einsum("bqk,bkd->bqd", p, v_c)
                # host-driven: next round's KV arrives only after this
                # round's compute (data dependence = sequential)
                k_n = jax.lax.ppermute(k_c, axis, perm)
                v_n = jax.lax.ppermute(v_c, axis, perm)
                return (k_n, v_n, m_new, l, acc), None

            m0 = jnp.full(q.shape[:2], -1e30)
            l0 = jnp.zeros(q.shape[:2])
            a0 = jnp.zeros_like(q)
            (k_f, v_f, m, l, acc), _ = jax.lax.scan(
                round_fn, (k, v, m0, l0, a0), jnp.arange(n))
            return (acc / jnp.maximum(l, 1e-30)[..., None])[None].astype(q.dtype)

        return run

    def _stream_split(self, mesh):
        """Overlap at graph level: the permute for round r+1 is issued before
        round r's compute and carries no dependence on it."""
        axis, n = self.axis, self.n_dev

        @functools.partial(shard_map, mesh=mesh, in_specs=P(axis),
                           out_specs=P(axis), check_vma=False)
        def run(q, k, v):
            q, k, v = q[0], k[0], v[0]
            me = jax.lax.axis_index(axis)
            sl = q.shape[1]
            perm = [(i, (i + 1) % n) for i in range(n)]
            qpos = me * sl + jnp.arange(sl)

            def round_fn(carry, r):
                k_c, v_c, m, l, acc = carry
                # issue the rotation FIRST: independent of this round's math
                k_n = jax.lax.ppermute(k_c, axis, perm)
                v_n = jax.lax.ppermute(v_c, axis, perm)
                src = (me - r) % n
                kpos = src * sl + jnp.arange(sl)
                s = jnp.einsum("bqd,bkd->bqk", q, k_c) / math.sqrt(self.hd)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, -1e30)
                m_new = jnp.maximum(m, jnp.max(s, -1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l = l * alpha + jnp.sum(p, -1)
                acc = acc * alpha[..., None] + jnp.einsum("bqk,bkd->bqd", p, v_c)
                return (k_n, v_n, m_new, l, acc), None

            m0 = jnp.full(q.shape[:2], -1e30)
            l0 = jnp.zeros(q.shape[:2])
            a0 = jnp.zeros_like(q)
            (k_f, v_f, m, l, acc), _ = jax.lax.scan(
                round_fn, (k, v, m0, l0, a0), jnp.arange(n))
            return (acc / jnp.maximum(l, 1e-30)[..., None])[None].astype(q.dtype)

        return run

    # directive -> kernel-knob mapping shared by build() and analytic_cost()
    # (the Workload.kernel_knobs search contract, docs/kernels.md)
    def kernel_knobs(self, d: Directive):
        k = super().kernel_knobs(d)      # kv_chunk (raw) + contexts
        fused = (d.placement == "TILE_FUSED" and d.completion != "BARRIER")
        k.update(
            # chunk-major rotation rounds (the FLUX-ring path); BARRIER
            # forces the whole-shard eager drain even under TILE_FUSED
            fused=fused,
            # COUNTER = per-chunk arrival ticks; SIGNAL drains a step's
            # chunks up front (per-edge wait, chunked issue)
            counter=(d.completion == "COUNTER" and fused),
            # lazy fence: the whole-shard rotation overlaps the round's
            # compute; ACQREL orders the fence eagerly, and BARRIER's
            # global-rendezvous semantics force the same serialized drain
            pipelined=d.placement in ("TILE_PIPELINED", "TILE_FUSED"),
            eager=((d.ordering == "ACQREL" or d.completion == "BARRIER")
                   and not fused))
        return k

    def collective_schedule(self, d: Directive):
        # the deployment-shard rotation schedule the ring kernel runs —
        # l0 (core/verify.py) statically checks it ahead of l1 build
        if d.backend == "XLA_COLLECTIVE":
            return None
        k = self.kernel_knobs(d)
        return make_ring_schedule(self.n_dev, self.sl, k["kv_chunk"],
                                  fused=k["fused"])

    def build(self, d: Directive, mesh):
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                return self._stream_split(mesh)
            return self.host_baseline(mesh)
        k = self.kernel_knobs(d)

        def run(q, k_in, v_in):
            return ring_kernel(q, k_in, v_in, mesh, axis=self.axis,
                               causal=True, fused=k["fused"],
                               counter=k["counter"], kv_chunk=k["kv_chunk"],
                               pipelined=k["pipelined"],
                               eager_wait=k["eager"],
                               contexts=k["contexts"])

        return run

    def default_tunables(self):
        # kv_chunk joins the TUNABLES grid: slow-path diff patches refine
        # the rotation chunk rows of the kernelized ring points
        return {"kv_chunk": 64}

    # --------------------------------------------------------- l3 cost model
    def analytic_cost(self, d: Directive, hw) -> float:
        return self.cost_breakdown(d, hw).total

    def cost_breakdown(self, d: Directive, hw) -> CostBreakdown:
        Seg = CostSegment
        n, BH, sl, hd = self.n_dev, self.BH, self.sl, self.hd
        flops_round = 4.0 * BH * sl * sl * hd          # qk^T + pv (causal ~1/2
        flops_round *= 0.5 * (1 + 1.0 / n)             # avg causal occupancy)
        t_comp = flops_round / hw.chip.peak_bf16_flops
        wire_round = 2 * BH * sl * hd * 2              # K and V, bf16
        t_wire = wire_round / hw.chip.ici_link_bw
        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" else SIGNAL_OVERHEAD
        if d.backend == "XLA_COLLECTIVE":
            if d.placement == "STREAM_SPLIT":
                per_round = max(t_comp, t_wire) + sync
                kind, path = "overlap", "xla_stream_split"
            else:
                per_round = t_comp + t_wire + sync + KERNEL_LAUNCH
                kind, path = "compute", "xla_host"
            return CostBreakdown(segments=(
                Seg("ring_rounds", n * per_round, kind,
                    meta={"rounds": n, "per_round_s": per_round,
                          "compute_s": t_comp, "wire_s": t_wire}),
                Seg("launch", KERNEL_LAUNCH * n, "launch",
                    meta={"launches": n}),     # per-round host launches
            ), meta={"path": path})
        # Pallas device-initiated: no host launches inside the ring
        k = self.kernel_knobs(d)
        if k["fused"]:
            # FLUX-ring credit: chunk c's rotation hides behind chunk c+1's
            # attention compute; per rotation step only the final chunk's
            # wire stays exposed (per_tile_exposed_s over the chunk count),
            # scaled by the send-window recycle stall. The schedule charges
            # TILE_SYNC per issued round and per completion tick.
            sched = make_ring_schedule(n, sl, k["kv_chunk"], fused=True)
            per_round = max(t_comp, t_wire)
            exposed = window_stall_factor(k["contexts"]) \
                * per_tile_exposed_s(wire_round, hw.chip.ici_link_bw,
                                     sched.nc)
            fixed = (sched.issued_rounds()
                     + sched.completion_ticks(k["counter"])) * TILE_SYNC
            return CostBreakdown(segments=(
                Seg("ring_rounds", sched.steps * per_round, "overlap",
                    meta={"rounds": sched.steps, "per_round_s": per_round,
                          "compute_s": t_comp, "wire_s": t_wire}),
                Seg("window_stall", sched.steps * exposed, "stall",
                    meta={"contexts": k["contexts"]}),
                Seg("final_compute", t_comp, "compute"),
                Seg("tile_sync", fixed, "sync",
                    meta={"issued_rounds": sched.issued_rounds(),
                          "ticks": sched.completion_ticks(k["counter"])}),
                Seg("launch", KERNEL_LAUNCH, "launch"),
            ), schedule=sched, knobs=k, meta={"path": "kernel_fused"})
        if k["pipelined"] and not k["eager"]:
            per_round = max(t_comp, t_wire) + sync     # lazy fence overlap
            kind, path = "overlap", "kernel_pipelined"
        else:                                          # DEFERRED / ACQREL
            per_round = t_comp + t_wire + sync
            kind, path = "compute", "kernel_deferred"
        return CostBreakdown(segments=(
            Seg("ring_rounds", n * per_round, kind,
                meta={"rounds": n, "per_round_s": per_round,
                      "compute_s": t_comp, "wire_s": t_wire}),
            Seg("launch", KERNEL_LAUNCH, "launch"),   # one cooperative launch
        ), knobs=k, meta={"path": path})
