"""Workload 5: the serving decode step — a DeepSeek-V3-style MoE layer at
serving shapes (one routed expert per rank + a replicated shared expert),
the executable home of the paper's headline two-stream discovery.

The step is ``MoEDispatch``'s quantize → dispatch → routed-expert FFN →
combine chain *plus* the shared-expert FFN every token takes. That shared
GEMM is the compute the serving loop must do anyway and it has no data
dependence on the dispatch wire — exactly TokenWeave's shape: overlap the
communication with compute you already owe.

Realizations (all semantics-preserving, cascade l2 checks):

* host (``CONSERVATIVE``) — strictly sequential: quantize, dispatch,
  routed FFN, combine, shared FFN.
* ``TokenWeave`` (XLA STREAM_SPLIT) — the shared-expert + self-chunk FFNs
  are issued with no dependence on the dispatch all-to-all, so XLA's
  latency-hiding scheduler runs the wire under them.
* DeepEP / FLUX (PALLAS_RDMA) — the fused ``kernels/moe_dispatch`` kernel
  with the shared-expert FFN as its **second stream**: issued inside the
  kernel against the open dispatch send window (after the last dispatch
  DMA is pushed, before the window drains), so the l3 model's overlap
  credit has an interpret-mode counterpart (``ScheduleProbe`` marks
  ``dispatch_issued → shared_ffn → dispatch_drained``).

Default shape: 4 ranks × 256 decode tokens, d=7168, f=2048 per expert and
for the shared expert (the DeepSeek-V3 decode-layer proportions); routing
uniform (``skew=1.0``) — a continuous decode batch mixes many users, so
per-expert load evens out relative to the prefill-time skew law.
"""
from __future__ import annotations

import jax

from repro.core.cost_model import (CostBreakdown, CostSegment,
                                   per_tile_exposed_s, window_stall_factor)
from repro.core.design_space import Directive
from repro.kernels.moe_dispatch import make_schedule, swiglu_ffn
from repro.workloads.base import (BARRIER_OVERHEAD, KERNEL_LAUNCH,
                                  SIGNAL_OVERHEAD, TILE_SYNC, register)
from repro.workloads.moe_dispatch import MoEDispatch


@register
class ServingStep(MoEDispatch):
    name = "serving_step"
    ring_topology = False
    kernelizable = True
    # collective_schedule is inherited from MoEDispatch: the serving step
    # issues the same dispatch/combine permutation at its decode token
    # count, so l0 static verification (core/verify.py) covers the
    # serving tier through the same seam — every kernelized serving
    # directive is lowered and checked before the engine ever builds it

    def __init__(self, n_dev=4, tokens_per_rank=256, d=7168, f=2048,
                 f_shared=2048, skew=1.0, axis="x", route_weights=None):
        super().__init__(n_dev=n_dev, tokens_per_rank=tokens_per_rank,
                         d=d, f=f, skew=skew, axis=axis,
                         route_weights=route_weights)
        self.f_shared = f_shared

    def degrade(self, live_ranks, capacity_factor=1.25):
        w = super().degrade(live_ranks, capacity_factor)
        if w is not self:
            w.f_shared = self.f_shared
        return w

    def state_bytes_per_rank(self):
        return super().state_bytes_per_rank() + 4 * (
            self.d * 2 * self.f_shared + self.f_shared * self.d)

    # ------------------------------------------------------------- inputs
    def example_inputs(self, key, mesh, T=None):
        import math

        import jax.numpy as jnp
        x, w1, w2 = super().example_inputs(key, mesh, T=T)
        ks = jax.random.split(jax.random.fold_in(key, 7), 2)
        s1 = jax.random.normal(ks[0], (self.d, 2 * self.f_shared),
                               jnp.float32) / math.sqrt(self.d)
        s2 = jax.random.normal(ks[1], (self.f_shared, self.d),
                               jnp.float32) / math.sqrt(self.f_shared)
        return x, w1, w2, s1, s2

    def _shared(self, x, s1, s2):
        return jax.vmap(lambda t: swiglu_ffn(t, s1, s2))(x)

    def reference(self, x, w1, w2, s1, s2):
        return super().reference(x, w1, w2) + self._shared(x, s1, s2)

    # ------------------------------------------------------------ builders
    def _make(self, mesh, *, overlap, wire_i8):
        routed = MoEDispatch._make(self, mesh, overlap=overlap,
                                   wire_i8=wire_i8)

        def run(x, w1, w2, s1, s2):
            # the shared FFN has no dependence on the dispatch wire: under
            # STREAM_SPLIT, XLA's scheduler runs it (and the self chunk)
            # while the all-to-all is in flight — the TokenWeave point
            return routed(x, w1, w2) + self._shared(x, s1, s2)

        return run

    def _make_kernel(self, mesh, d: Directive):
        from repro.kernels.moe_dispatch import moe_dispatch_combine
        k = self.kernel_knobs(d)

        def run(x, w1, w2, s1, s2):
            y, ys = moe_dispatch_combine(
                x, w1, w2, mesh, axis=self.axis,
                counts=self._counts(x.shape[1]),
                block_tokens=k["block_tokens"], tight=k["tight"],
                pipelined=k["pipelined"], barrier=k["barrier"],
                tile_fused=k["tile_fused"], combine_tile=k["combine_tile"],
                contexts=k["contexts"], wire_i8=bool(k["wire_i8"]),
                shared=(x, s1, s2))
            return y + ys

        return run

    # --------------------------------------------------------- l3 cost model
    def cost_breakdown(self, d: Directive, hw) -> CostBreakdown:
        Seg = CostSegment
        n, T, dm, f, fs = self.n_dev, self.T, self.d, self.f, self.f_shared
        counts = self._counts(T)
        C = int(counts.max())
        kernel = d.backend in ("PALLAS_RDMA", "HYBRID")
        k = self.kernel_knobs(d) if kernel else None
        tight = k["tight"] if kernel \
            else bool(d.granularity == "PER_PEER" and d.tunable("tight", 1))
        wire_i8 = bool(d.tunable("wire_i8", 0))
        bytes_per = 1 if wire_i8 else 2
        recv_tokens = int(counts[0]) * n if tight else C * n
        self_tokens = int(counts[0])
        t_routed = 3 * 2 * recv_tokens * dm * f / hw.chip.peak_bf16_flops
        t_self = t_routed * self_tokens / max(1, recv_tokens)
        t_remote = t_routed - t_self
        t_shared = 3 * 2 * T * dm * fs / hw.chip.peak_bf16_flops
        sent = (counts.sum() - counts[0]) if tight else C * (n - 1)
        t_disp = sent * dm * bytes_per / hw.chip.ici_link_bw
        t_comb = sent * dm * 2 / hw.chip.ici_link_bw
        t_quant = (2 * T * dm * 2 / hw.chip.hbm_bw) if wire_i8 else 0.0

        if kernel:
            B = k["block_tokens"]
            sched = make_schedule(counts, B, k["tight"])
            disp_rounds = sched.issued_rounds(elide_dummy=True)
            ticks = sched.combine_ticks(k["combine_tile"], rank=0,
                                        elide_dummy=True) \
                if k["tile_fused"] \
                else sched.combine_issued_rounds(0, elide_dummy=True)
            if k["tile_fused"]:
                sync = 0.0
            elif d.completion == "BARRIER":
                sync = BARRIER_OVERHEAD
            else:
                sync = SIGNAL_OVERHEAD * max(1, n - 1)
            tail = (
                Seg("quant", t_quant, "quant"),
                Seg("sync", sync, "sync"),
                Seg("launch", KERNEL_LAUNCH, "launch"),
                Seg("tile_sync", (disp_rounds + ticks) * TILE_SYNC, "sync",
                    meta={"issued_rounds": disp_rounds, "ticks": ticks}),
            )
            if k["tile_fused"]:
                # FLUX + second stream: the compute track runs shared FFN
                # (issued against the open send window) then the tiled
                # routed FFN as arrivals land; the wire track is dispatch.
                startup = t_disp / max(1, disp_rounds)
                span = max(t_disp, startup + t_shared + t_routed)
                window = window_stall_factor(k["contexts"])
                return CostBreakdown(segments=(
                    Seg("two_stream_span", span, "overlap",
                        meta={"wire_s": t_disp,
                              "compute_s": startup + t_shared + t_routed}),
                    Seg("window_stall", window * per_tile_exposed_s(
                        sent * dm * 2, hw.chip.ici_link_bw, ticks), "stall",
                        meta={"contexts": k["contexts"]}),
                ) + tail, schedule=sched, knobs=k,
                    meta={"path": "kernel_two_stream"})
            # DeepEP-style deferred/pipelined: the shared FFN still issues
            # against the open dispatch window (the kernel runs it between
            # the last push and the drain on every completion path)
            return CostBreakdown(segments=(
                Seg("two_stream", max(t_disp, t_shared), "overlap",
                    meta={"wire_s": t_disp, "compute_s": t_shared}),
                Seg("expert_ffn", t_routed, "compute"),
                Seg("combine", t_comb, "wire"),
            ) + tail, schedule=sched, knobs=k,
                meta={"path": "kernel_deferred_two_stream"})

        sync = BARRIER_OVERHEAD if d.completion == "BARRIER" \
            else SIGNAL_OVERHEAD
        launches = KERNEL_LAUNCH * 5              # + the shared-expert GEMM
        if d.placement == "STREAM_SPLIT":
            # TokenWeave: dispatch hidden behind shared + self-chunk FFNs
            stage1 = max(t_disp + t_quant, t_shared + t_self)
            return CostBreakdown(segments=(
                Seg("two_stream", stage1, "overlap",
                    meta={"wire_s": t_disp + t_quant,
                          "compute_s": t_shared + t_self}),
                Seg("remote_ffn", t_remote, "compute"),
                Seg("combine", t_comb, "wire"),
                Seg("sync", sync, "sync"),
                Seg("launch", launches, "launch"),
            ), meta={"path": "xla_two_stream"})
        return CostBreakdown(segments=(
            Seg("quant", t_quant, "quant"),
            Seg("dispatch", t_disp, "wire"),
            Seg("expert_ffn", t_routed, "compute"),
            Seg("combine", t_comb, "wire"),
            Seg("shared_ffn", t_shared, "compute"),
            Seg("sync", sync, "sync"),
            Seg("launch", launches, "launch"),
        ), meta={"path": "xla_host"})
