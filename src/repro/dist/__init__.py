from repro.dist.collectives import compressed_psum, hierarchical_psum
from repro.dist.sharding import Rules, sanitize_specs, zero_spec

__all__ = ["Rules", "sanitize_specs", "zero_spec", "compressed_psum",
           "hierarchical_psum"]
