"""Cross-device reduction helpers beyond stock ``psum``.

``compressed_psum`` trades exactness for wire bytes: each device quantizes
its contribution to int8 with per-group scales before the reduction (the
DCN-bandwidth-bound regime; ~1% relative error on unit-scale activations).

``hierarchical_psum`` decomposes a global reduction into an intra-pod psum
(ICI, fast) followed by a cross-pod psum (DCN, slow) — optionally
compressing only the DCN hop, where bandwidth is ~20x scarcer. The
decomposition is exact when ``compress_dcn=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_i8(x, group_size):
    """Per-group int8 quantization along the last dim. Returns dequantized
    values (the wire carries q + one f32 scale per group)."""
    shape = x.shape
    d = shape[-1]
    g = max(1, min(group_size, d))
    pad = (-d) % g
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xg = xp.reshape(shape[:-1] + (-1, g))
    scale = jnp.max(jnp.abs(xg), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xg / scale), -127, 127)
    deq = (q * scale).reshape(shape[:-1] + (d + pad,))
    return deq[..., :d]


def compressed_psum(x, axes, group_size=8):
    """int8-compressed all-reduce over ``axes`` (named mesh axes)."""
    return jax.lax.psum(_quantize_i8(x, group_size), axes)


def hierarchical_psum(x, *, pod_axis="pod", inner_axes=("data",),
                      compress_dcn=False, group_size=8):
    """Intra-pod psum then cross-pod psum; optionally int8-compress the
    cross-pod (DCN) hop only."""
    inner = jax.lax.psum(x, inner_axes)
    if compress_dcn:
        inner = _quantize_i8(inner, group_size)
    return jax.lax.psum(inner, pod_axis)
