"""Logical-axis sharding rules (GSPMD-style named-axis tables).

``Rules`` maps *logical* tensor axes ("batch", "heads", "ff", "vocab",
"experts_data", ...) to *mesh* axes per execution kind (train / prefill /
decode). Model code never names mesh axes directly: it asks
``rules.axes("heads")`` for a PartitionSpec entry, ``rules.shard(x, ...)``
for an activation constraint, or ``rules.param_spec(shape, ...)`` for a
divisibility-checked parameter spec. Everything here is a sharding *hint*
(constraints and placements), never a semantic change — the sharded-model
suites assert numerical equivalence against the unsharded oracle.

Conventions (single pod: ("data", "model"); multi-pod adds a leading
"pod" axis that behaves as extra data parallelism):

  batch         -> data (+pod)       activations' leading dim
  heads/kv_heads/ff/vocab -> model   Megatron-style tensor parallelism
  experts_data  -> data              expert-parallel all-to-all mode
  experts_model -> model             expert-sharded replicated mode
  seq_act/seq_res -> model           sequence-parallel activation shards
  seq_kv        -> model iff long_context (500k-token cells) else unsharded
  zero          -> (pod, data)       ZeRO-style optimizer-state sharding
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

DP_AXIS_NAMES = ("pod", "data")
TP_AXIS_NAMES = ("model",)


def _flatten(entry):
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _compact(axes):
    """() -> None, 1-tuple -> name, n-tuple -> tuple (PartitionSpec style)."""
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


class Rules:
    def __init__(self, mesh, kind: str = "train", *, long_context=False):
        self.mesh = mesh
        self.kind = kind
        self.long_context = long_context
        names = tuple(mesh.axis_names) if mesh is not None else ()
        self._dp = tuple(a for a in names if a in DP_AXIS_NAMES)
        self._tp = tuple(a for a in names if a in TP_AXIS_NAMES)
        dp, tp = _compact(self._dp), _compact(self._tp)
        self.table = {
            "batch": dp,
            "zero": self._dp,
            "heads": tp,
            "kv_heads": tp,
            "ff": tp,
            "vocab": tp,
            "experts_data": dp,
            "experts_model": tp,
            "seq_act": tp,
            "seq_res": tp,
            "seq_kv": tp if long_context else None,
        }

    # ------------------------------------------------------------- queries
    @property
    def dp_axes(self):
        return self._dp

    @property
    def tp_axes(self):
        return self._tp

    def axes(self, name):
        """Mesh axes for a logical axis name (None = replicated)."""
        return self.table.get(name)

    def _axis_size(self, entry):
        size = 1
        for a in _flatten(entry):
            size *= int(self.mesh.shape[a])
        return size

    def size(self, name):
        return self._axis_size(self.axes(name))

    def dp_size(self):
        return self._axis_size(self._dp)

    # ----------------------------------------------------------- builders
    def _fit(self, entry, dim):
        """Keep a spec entry only if the dim divides over it evenly."""
        if entry is None:
            return None
        size = self._axis_size(entry)
        return entry if size and dim % size == 0 else None

    def param_spec(self, shape, *names):
        """Divisibility-checked PartitionSpec for a concrete shape. Entries
        are logical axis names or None (replicated dim)."""
        entries = []
        for dim, nm in zip(shape, names):
            ax = self.axes(nm) if isinstance(nm, str) else nm
            entries.append(self._fit(ax, dim))
        return P(*entries)

    def shard(self, x, *names):
        """Activation sharding constraint over logical axis names."""
        if self.mesh is None:
            return x
        spec = self.param_spec(x.shape, *names)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def zero_spec(spec, shape, rules: Rules):
    """ZeRO-style optimizer-state spec: additionally shard the first
    replicated, evenly-divisible dim over the data axes. A spec that
    already uses any data axis is returned unchanged."""
    dp_axes = tuple(rules.table.get("zero") or rules._dp)
    if not dp_axes:
        return spec
    used = {a for entry in spec for a in _flatten(entry)}
    if used & set(dp_axes):
        return spec
    dp = 1
    for a in dp_axes:
        dp *= int(rules.mesh.shape[a])
    if dp <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries):
        if e is None and shape[i] % dp == 0:
            entries[i] = _compact(dp_axes)
            return P(*entries)
    return spec


def sanitize_specs(specs, sds, mesh):
    """Drop spec entries that reference unknown mesh axes or that do not
    divide the corresponding dim evenly (strict-divisible shardings only —
    GSPMD would pad, we refuse instead)."""
    sizes = {a: int(s) for a, s in dict(mesh.shape).items()}

    def fix(spec, s):
        shape = s.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, e in zip(shape, entries):
            axes = _flatten(e)
            size = 1
            known = all(a in sizes for a in axes)
            for a in axes:
                size *= sizes.get(a, 1)
            out.append(e if axes and known and dim % size == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, sds, is_leaf=lambda x: isinstance(x, P))
