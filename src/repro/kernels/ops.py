"""jit'd public wrappers for the Pallas kernels in this package."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.ring_attention import ring_attention as _ring
from repro.kernels.gemm_allgather import gemm_allgather as _ga
from repro.kernels.kv_shuttle import kv_shuttle as _kv


@partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal=True, q_block=128, kv_block=128,
                    interpret=True):
    return _fa(q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
               interpret=interpret)


def ring_attention(q, k, v, mesh, *, axis="x", causal=True, pipelined=True,
                   eager_wait=False, fused=False, counter=False,
                   kv_chunk=None, contexts=2):
    fn = jax.jit(partial(_ring, mesh=mesh, axis=axis, causal=causal,
                         pipelined=pipelined, eager_wait=eager_wait,
                         fused=fused, counter=counter, kv_chunk=kv_chunk,
                         contexts=contexts))
    return fn(q, k, v)


def gemm_allgather(a_shards, b, mesh, *, axis="x", tile_m=128, fused=True,
                   counter=False, contexts=2):
    fn = jax.jit(partial(_ga, mesh=mesh, axis=axis, tile_m=tile_m,
                         fused=fused, counter=counter, contexts=contexts))
    return fn(a_shards, b)


def kv_shuttle(x, wk, wv, mesh, *, axis="x", chained=True, fused=False,
               counter=False, kv_chunk=None, contexts=2):
    fn = jax.jit(partial(_kv, mesh=mesh, axis=axis, chained=chained,
                         fused=fused, counter=counter, kv_chunk=kv_chunk,
                         contexts=contexts))
    return fn(x, wk, wv)
