"""Blockwise flash attention Pallas TPU kernel (compute core).

Online-softmax over KV blocks with explicit BlockSpec VMEM tiling. The grid
is (batch*heads, q_blocks, kv_blocks); the kv dimension is the innermost
(sequential on TPU), so the f32 accumulator scratch carries across kv steps.
Causal masking skips fully-masked kv blocks via pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *, causal, scale,
               q_block, kv_block):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q_start = qi * q_block
    k_start = ki * kv_block
    run = (not causal) or (k_start <= q_start + q_block - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                    # (qb, hd)
        k = k_ref[0].astype(jnp.float32)                    # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_i[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, q_block=128, kv_block=128,
                    interpret=True):
    """q/k/v: (BH, S, hd) -> (BH, S, hd)."""
    BH, S, hd = q.shape
    Skv = k.shape[1]
    assert S % q_block == 0 and Skv % kv_block == 0, (S, Skv, q_block, kv_block)
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, S // q_block, Skv // kv_block)
    kern = functools.partial(_fa_kernel, causal=causal, scale=scale,
                             q_block=q_block, kv_block=kv_block)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
