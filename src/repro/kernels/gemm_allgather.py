"""Fused GEMM + device-initiated AllGather (paper workload 4) — the
FLUX/CoCoNet-grade tile-fused realization.

Each device computes ``C_local = A_local @ B`` and broadcasts it to every
peer by remote DMA into the peer's output slab (the LSA-analogue: direct
stores into peer memory — here single-hop ICI remote copies). Rank ``r``'s
slab lives at rows ``[r*M_l, (r+1)*M_l)`` of every device's output, so the
source and destination offsets of every transfer coincide.

**Broadcast-round schedule.** The schedule is trace time
(:class:`BroadcastSchedule`, the gemm_allgather analogue of
``moe_dispatch.DispatchSchedule``): rounds ``(off, t)`` where in round
``(off, t)`` rank ``r`` sends tile ``t`` of its slab to peer ``(r + off) %
n`` and receives the matching tile from ``(r - off) % n`` — a shift
permutation, so the legacy 0.4.x pallas interpreter discharges it in
lockstep. The broadcast is *dense* (every rank ships every tile to every
peer), so unlike the MoE dispatch schedule there are no dummy rounds and
nothing to elide: the lockstep schedule IS the hardware schedule.

**Placement realizations (design-space P):**
  TILE_FUSED — rounds are ordered tile-major: tile ``t``'s broadcast DMAs
    are issued the moment tile ``t``'s GEMM finishes, while tile ``t+1``
    computes (G=PER_TILE).
  DEFERRED   — one whole-slab round per peer offset after the full local
    GEMM (G=PER_PEER; the fast-path conservative shape). Both paths share
    the same schedule object; only ``rounds``/``rows_per_round`` differ.

**Completion (design-space K):** ``COUNTER`` (the FLUX point) consumes
arrivals one tile at a time — while tile ``t``'s sends are in flight the
kernel ticks off tile ``t-1``'s landings from every peer, so readiness is
per-tile, not per-edge. ``SIGNAL`` waits once per inbound edge after the
tile loop. ``BARRIER`` (and any non-fused placement) drains whole slabs.

**Send window.** ``contexts`` bounds the in-flight send window: at most
``contexts`` broadcast rounds' send semaphores are unawaited; the oldest is
``wait_send``-ed before the next round issues (double/quad buffering) —
replacing the old kernel's wait-everything-at-``t == nt-1`` drain.

Per-edge semaphores: slot ``p`` of the send array counts outstanding sends
to peer ``p``; slot ``s`` of the receive array counts arrivals from source
``s`` (routed through ``_sem_slot`` — see docs/kernels.md for the legacy
vs. sender-driven slot convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (interpret_params, shard_map, sync_copy,
                          compiler_params as tpu_compiler_params)
# The schedule machinery is defined once, in repro.core.schedule (the
# collective-schedule contract); re-exported here for the kernel's callers.
from repro.core.schedule import (BroadcastSchedule, SendWindow,  # noqa: F401
                                 make_broadcast_schedule, sanitize_tile_m,
                                 sem_slot)


# ------------------------------------------------------------------- kernel


def _ga_kernel(a_ref, b_ref, o_ref, atile, bbuf, ctile, ssem, rsem,
               *, axis, sched: BroadcastSchedule, counter, contexts,
               probe=None):
    n, M_l, tm, nt = sched.n, sched.M_l, sched.tile_m, sched.nt
    N = b_ref.shape[1]
    me = jax.lax.axis_index(axis)

    # GEMM operands live in ANY (HBM): B is staged into VMEM once, each A
    # tile per round — the interpreter tolerates direct ANY reads but
    # Mosaic on real TPU requires DMA-staged VMEM operands.
    sync_copy(b_ref, bbuf)

    # Receive-slot convention routed through the shared contract helper
    # (core/schedule.py::sem_slot): slot s = edge from source rank s,
    # under either the legacy lockstep or the sender-driven engine.
    def _sem_slot(inbound_src):
        return sem_slot(me, inbound_src)

    def edge_dma(off, rel, rows):
        """Round (off, .): ship rows [rel, rel+rows) of my slab to peer
        (me+off)%n; the matching inbound rows land from (me-off)%n."""
        peer = jax.lax.rem(me + off, n)
        src = jax.lax.rem(me - off + n, n)
        rows0 = me * M_l + rel
        return pltpu.make_async_remote_copy(
            src_ref=o_ref.at[pl.ds(rows0, rows)],
            dst_ref=o_ref.at[pl.ds(rows0, rows)],
            send_sem=ssem.at[peer], recv_sem=rsem.at[_sem_slot(src)],
            device_id=peer, device_id_type=pltpu.DeviceIdType.MESH)

    def gemm_tile(t):
        # operands and result both stage through VMEM scratch (atile/bbuf
        # in, ctile out); a_ref/o_ref live in ANY
        sync_copy(a_ref.at[pl.ds(t * tm, tm)], atile)
        ctile[...] = jax.lax.dot_general(
            atile[...], bbuf[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(ctile.dtype)
        sync_copy(ctile, o_ref.at[pl.ds(me * M_l + t * tm, tm)])

    def wait_arrivals(off, rows):
        recv_probe()
        src = jax.lax.rem(me - off + n, n)
        pltpu.semaphore_wait(rsem.at[src], rows * N)

    # contexts-deep send window over the trace-time round order (the shared
    # schedule.SendWindow): every DMA is issued unconditionally (lockstep
    # rule), the window only bounds how many rounds' send semaphores stay
    # unawaited. An attached ScheduleProbe (core/trace.py) records the
    # trace-time issue/wait order for the observed-vs-modeled check.
    if probe is None:
        window = SendWindow(contexts)
        recv_probe = lambda: None
    else:
        # the probe must observe the window's true order — retire-oldest
        # strictly before the new round starts — so both hooks record
        pending = []

        def _start(cps):
            probe.issue(*pending.pop(0))
            for cp in cps:
                cp.start()

        def _retire(cps):
            probe.wait_send()
            for cp in cps:
                cp.wait_send()

        window = SendWindow(contexts, start=_start, wait=_retire)
        recv_probe = probe.wait_recv

    def issue(off, rel, rows):
        if probe is not None:
            pending.append((off, rel // rows))
        window.push([edge_dma(off, rel, rows)])

    if sched.fused:
        # TILE_FUSED: tile t's broadcast issues the moment its GEMM ends,
        # overlapping tile t+1's compute — lockstep (off, t) order.
        for t in range(nt):
            gemm_tile(t)
            for off in range(1, n):
                issue(off, t * tm, tm)
            if counter and t > 0:
                # COUNTER per-tile ticks: consume tile t-1's arrivals from
                # every peer while tile t's sends are still in flight
                for off in range(1, n):
                    wait_arrivals(off, tm)
        window.drain()
        if counter:
            for off in range(1, n):          # the final tile's ticks
                wait_arrivals(off, tm)
        else:
            for off in range(1, n):          # per-edge SIGNAL drain
                wait_arrivals(off, nt * tm)
    else:
        # DEFERRED: one whole-slab round per peer after the full GEMM,
        # same schedule object with rows_per_round = M_l.
        for t in range(nt):
            gemm_tile(t)
        for off in range(1, n):
            issue(off, 0, M_l)
        window.drain()
        for off in range(1, n):
            wait_arrivals(off, M_l)


def gemm_allgather_sharded(a, b, *, axis, sched: BroadcastSchedule = None,
                           n_dev=None, tile_m=128, fused=True, counter=False,
                           contexts=2, interpret=None, probe=None):
    """Per-device fn (under shard_map). a: (M_l, K) local; b: (K, N)
    replicated. Returns (n_dev*M_l, N) — the full gathered GEMM output on
    every device. An explicit ``sched`` takes precedence: the
    ``n_dev``/``tile_m``/``fused`` knobs are consulted only to build one
    when ``sched`` is None. ``probe`` (a ``core/trace.py::ScheduleProbe``)
    records the trace-time DMA issue/wait order for the observed-vs-modeled
    schedule check."""
    M_l, K = a.shape
    N = b.shape[1]
    if sched is None:
        assert n_dev is not None, \
            "gemm_allgather_sharded needs an explicit sched= or n_dev="
        sched = make_broadcast_schedule(n_dev, M_l, tile_m, fused)
    assert sched.M_l == M_l, (sched.M_l, M_l)
    assert M_l % sched.tile_m == 0, (M_l, sched.tile_m)
    kern = functools.partial(_ga_kernel, axis=axis, sched=sched,
                             counter=bool(counter), contexts=contexts,
                             probe=probe)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((sched.n * M_l, N), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((sched.tile_m, K), a.dtype),  # staged A tile operand
            pltpu.VMEM((K, N), b.dtype),             # staged B operand
            pltpu.VMEM((sched.tile_m, N), a.dtype),  # GEMM tile staging
            pltpu.SemaphoreType.DMA((sched.n,)),     # per-peer send slots
            pltpu.SemaphoreType.DMA((sched.n,)),     # per-source recv slots
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=11),
    )(a, b)


def gemm_allgather(a_shards, b, mesh, *, axis="x", tile_m=128, fused=True,
                   counter=False, contexts=2, probe=None):
    """Global entry: a_shards (n, M_l, K) sharded over axis; b replicated.
    ``tile_m`` is sanitized to a divisor of M_l; ``counter`` selects
    per-tile completion ticks (the FLUX point) on the fused path. ``probe``
    (a ``core/trace.py::ScheduleProbe``) records the trace-time DMA
    issue/wait order for ``probe.check(sched, contexts)``."""
    from jax.sharding import PartitionSpec as P
    n_dev = mesh.shape[axis]
    sched = make_broadcast_schedule(n_dev, a_shards.shape[1], tile_m, fused)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis), P(None, None)),
                       out_specs=P(axis), check_vma=False)
    def run(a, bb):
        out = gemm_allgather_sharded(a[0], bb, axis=axis, sched=sched,
                                     counter=counter, contexts=contexts,
                                     probe=probe)
        return out[None]

    return run(a_shards, b)
