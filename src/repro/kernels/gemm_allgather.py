"""Fused GEMM + device-initiated AllGather (paper workload 4) — the
FLUX/CoCoNet-grade tile-fused realization.

Each device computes ``C_local = A_local @ B`` and broadcasts it to every
peer by remote DMA into the peer's output slab (the LSA-analogue: direct
stores into peer memory — here single-hop ICI remote copies). Rank ``r``'s
slab lives at rows ``[r*M_l, (r+1)*M_l)`` of every device's output, so the
source and destination offsets of every transfer coincide.

**Broadcast-round schedule.** The schedule is trace time
(:class:`BroadcastSchedule`, the gemm_allgather analogue of
``moe_dispatch.DispatchSchedule``): rounds ``(off, t)`` where in round
``(off, t)`` rank ``r`` sends tile ``t`` of its slab to peer ``(r + off) %
n`` and receives the matching tile from ``(r - off) % n`` — a shift
permutation, so the legacy 0.4.x pallas interpreter discharges it in
lockstep. The broadcast is *dense* (every rank ships every tile to every
peer), so unlike the MoE dispatch schedule there are no dummy rounds and
nothing to elide: the lockstep schedule IS the hardware schedule.

**Placement realizations (design-space P):**
  TILE_FUSED — rounds are ordered tile-major: tile ``t``'s broadcast DMAs
    are issued the moment tile ``t``'s GEMM finishes, while tile ``t+1``
    computes (G=PER_TILE).
  DEFERRED   — one whole-slab round per peer offset after the full local
    GEMM (G=PER_PEER; the fast-path conservative shape). Both paths share
    the same schedule object; only ``rounds``/``rows_per_round`` differ.

**Completion (design-space K):** ``COUNTER`` (the FLUX point) consumes
arrivals one tile at a time — while tile ``t``'s sends are in flight the
kernel ticks off tile ``t-1``'s landings from every peer, so readiness is
per-tile, not per-edge. ``SIGNAL`` waits once per inbound edge after the
tile loop. ``BARRIER`` (and any non-fused placement) drains whole slabs.

**Send window.** ``contexts`` bounds the in-flight send window: at most
``contexts`` broadcast rounds' send semaphores are unawaited; the oldest is
``wait_send``-ed before the next round issues (double/quad buffering) —
replacing the old kernel's wait-everything-at-``t == nt-1`` drain.

Per-edge semaphores: slot ``p`` of the send array counts outstanding sends
to peer ``p``; slot ``s`` of the receive array counts arrivals from source
``s`` (routed through ``_sem_slot`` — see docs/kernels.md for the legacy
vs. sender-driven slot convention).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (LEGACY_INTERPRET, interpret_params, shard_map,
                          sync_copy,
                          compiler_params as tpu_compiler_params)

# ----------------------------------------------------------------- schedule


def sanitize_tile_m(tile_m, M_l):
    """Largest divisor of ``M_l`` that is <= the requested tile: slow-path
    diff patches draw ``tile_m`` from the central ``TUNABLES`` grid, which
    need not divide a given local slab — the kernel contract requires an
    exact divisor. One sanitizer algorithm for the whole package: this is
    ``moe_dispatch.sanitize_combine_tile`` over the slab dimension."""
    from repro.kernels.moe_dispatch import sanitize_combine_tile
    return sanitize_combine_tile(tile_m, M_l)


@dataclass(frozen=True)
class BroadcastSchedule:
    """Trace-time broadcast-round schedule + wire accounting (rows/rank).

    ``rounds`` is the lockstep round list ``[(off, t), ...]``: in round
    ``(off, t)`` rank ``r`` sends rows ``[t*rows_per_round, ...)`` of its
    slab to peer ``(r + off) % n`` and receives the matching rows from
    ``(r - off) % n`` — a shift permutation (exactly one incoming copy per
    rank per round), identical on every rank. The fused schedule is
    tile-major so tile ``t``'s rounds issue before tile ``t+1`` computes;
    the DEFERRED schedule is one whole-slab round per offset.
    """
    n: int
    M_l: int
    tile_m: int              # sanitized: always divides M_l
    fused: bool

    @property
    def nt(self):
        return self.M_l // self.tile_m

    @property
    def rows_per_round(self):
        return self.tile_m if self.fused else self.M_l

    @property
    def rounds(self):
        if self.fused:
            return [(off, t) for t in range(self.nt)
                    for off in range(1, self.n)]
        return [(off, 0) for off in range(1, self.n)]

    def issued_rounds(self):
        """Broadcast ``dma_start`` rounds each rank issues — dense, so no
        elided/lockstep split: ``(n-1)*nt`` fused, ``n-1`` deferred."""
        return len(self.rounds)

    def wire_rows(self, rank=0):
        """Rows each rank broadcasts off-rank (dense: identical on every
        rank, and identical for the fused and deferred schedules — the
        schedule changes *when* rows move, never how many)."""
        return (self.n - 1) * self.M_l

    def completion_ticks(self, counter=True):
        """Receive-side readiness ticks: COUNTER consumes arrivals one
        tile at a time (one tick per inbound ``(src, tile)`` edge); SIGNAL
        and the DEFERRED slab path wait once per inbound edge."""
        if self.fused and counter:
            return (self.n - 1) * self.nt
        return self.n - 1

    def send_window_depths(self, contexts):
        """See ``moe_dispatch.send_window_depths`` (the shared trace-time
        mirror of the kernels' windowed-issue algorithm)."""
        from repro.kernels.moe_dispatch import send_window_depths
        return send_window_depths(self.rounds, contexts)


def make_broadcast_schedule(n_dev, M_l, tile_m=128, fused=True):
    return BroadcastSchedule(n=int(n_dev), M_l=int(M_l),
                             tile_m=sanitize_tile_m(tile_m, M_l),
                             fused=bool(fused))


# ------------------------------------------------------------------- kernel


def _ga_kernel(a_ref, b_ref, o_ref, ctile, ssem, rsem,
               *, axis, sched: BroadcastSchedule, counter, contexts):
    n, M_l, tm, nt = sched.n, sched.M_l, sched.tile_m, sched.nt
    N = b_ref.shape[1]
    me = jax.lax.axis_index(axis)

    # Receive-slot convention: slot s = edge from source rank s. The legacy
    # lockstep discharge bumps the slot named by the *receiver's own*
    # descriptor (my inbound peer this round); faithful sender-driven RDMA
    # bumps the slot the *sender* names (its own rank). Same convention
    # either way once routed through here (docs/kernels.md).
    def _sem_slot(inbound_src):
        return inbound_src if LEGACY_INTERPRET else me

    def edge_dma(off, rel, rows):
        """Round (off, .): ship rows [rel, rel+rows) of my slab to peer
        (me+off)%n; the matching inbound rows land from (me-off)%n."""
        peer = jax.lax.rem(me + off, n)
        src = jax.lax.rem(me - off + n, n)
        rows0 = me * M_l + rel
        return pltpu.make_async_remote_copy(
            src_ref=o_ref.at[pl.ds(rows0, rows)],
            dst_ref=o_ref.at[pl.ds(rows0, rows)],
            send_sem=ssem.at[peer], recv_sem=rsem.at[_sem_slot(src)],
            device_id=peer, device_id_type=pltpu.DeviceIdType.MESH)

    def gemm_tile(t):
        # compute stages through the VMEM ctile scratch (Mosaic requires
        # compute results in VMEM on real hardware; o_ref lives in ANY)
        ctile[...] = jax.lax.dot_general(
            a_ref[pl.ds(t * tm, tm)], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(ctile.dtype)
        sync_copy(ctile, o_ref.at[pl.ds(me * M_l + t * tm, tm)])

    def wait_arrivals(off, rows):
        src = jax.lax.rem(me - off + n, n)
        pltpu.semaphore_wait(rsem.at[src], rows * N)

    # contexts-deep send window over the trace-time round order: every DMA
    # is issued unconditionally (lockstep rule), the window only bounds how
    # many send semaphores stay unawaited.
    cap = max(1, int(contexts))
    inflight = []

    def issue(off, rel, rows):
        if len(inflight) >= cap:
            inflight.pop(0).wait_send()
        cp = edge_dma(off, rel, rows)
        cp.start()
        inflight.append(cp)

    if sched.fused:
        # TILE_FUSED: tile t's broadcast issues the moment its GEMM ends,
        # overlapping tile t+1's compute — lockstep (off, t) order.
        for t in range(nt):
            gemm_tile(t)
            for off in range(1, n):
                issue(off, t * tm, tm)
            if counter and t > 0:
                # COUNTER per-tile ticks: consume tile t-1's arrivals from
                # every peer while tile t's sends are still in flight
                for off in range(1, n):
                    wait_arrivals(off, tm)
        for cp in inflight:
            cp.wait_send()
        if counter:
            for off in range(1, n):          # the final tile's ticks
                wait_arrivals(off, tm)
        else:
            for off in range(1, n):          # per-edge SIGNAL drain
                wait_arrivals(off, nt * tm)
    else:
        # DEFERRED: one whole-slab round per peer after the full GEMM,
        # same schedule object with rows_per_round = M_l.
        for t in range(nt):
            gemm_tile(t)
        for off in range(1, n):
            issue(off, 0, M_l)
        for cp in inflight:
            cp.wait_send()
        for off in range(1, n):
            wait_arrivals(off, M_l)


def gemm_allgather_sharded(a, b, *, axis, sched: BroadcastSchedule = None,
                           n_dev=None, tile_m=128, fused=True, counter=False,
                           contexts=2, interpret=None):
    """Per-device fn (under shard_map). a: (M_l, K) local; b: (K, N)
    replicated. Returns (n_dev*M_l, N) — the full gathered GEMM output on
    every device. An explicit ``sched`` takes precedence: the
    ``n_dev``/``tile_m``/``fused`` knobs are consulted only to build one
    when ``sched`` is None."""
    M_l, K = a.shape
    N = b.shape[1]
    if sched is None:
        sched = make_broadcast_schedule(n_dev, M_l, tile_m, fused)
    assert sched.M_l == M_l, (sched.M_l, M_l)
    assert M_l % sched.tile_m == 0, (M_l, sched.tile_m)
    kern = functools.partial(_ga_kernel, axis=axis, sched=sched,
                             counter=bool(counter), contexts=contexts)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((sched.n * M_l, N), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((sched.tile_m, N), a.dtype),  # GEMM tile staging
            pltpu.SemaphoreType.DMA((sched.n,)),     # per-peer send slots
            pltpu.SemaphoreType.DMA((sched.n,)),     # per-source recv slots
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=11),
    )(a, b)


def gemm_allgather(a_shards, b, mesh, *, axis="x", tile_m=128, fused=True,
                   counter=False, contexts=2):
    """Global entry: a_shards (n, M_l, K) sharded over axis; b replicated.
    ``tile_m`` is sanitized to a divisor of M_l; ``counter`` selects
    per-tile completion ticks (the FLUX point) on the fused path."""
    from jax.sharding import PartitionSpec as P
    n_dev = mesh.shape[axis]
    sched = make_broadcast_schedule(n_dev, a_shards.shape[1], tile_m, fused)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis), P(None, None)),
                       out_specs=P(axis), check_vma=False)
    def run(a, bb):
        out = gemm_allgather_sharded(a[0], bb, axis=axis, sched=sched,
                                     counter=counter, contexts=contexts)
        return out[None]

    return run(a_shards, b)
