"""Fused GEMM + device-initiated AllGather (paper workload 4).

Each device computes C_local = A_local @ B and broadcasts it to every peer by
remote DMA into the peer's output slab (the LSA-analogue: direct stores into
peer memory — here single-hop ICI remote copies).

Placement realizations (design-space P):
  TILE_FUSED — the broadcast of tile t starts as soon as tile t's GEMM
    finishes, while tile t+1 computes (per-tile granularity G=PER_TILE).
  DEFERRED   — one transfer per peer after the full local GEMM
    (G=PER_PEER; the fast-path conservative shape).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import (interpret_params, shard_map, sync_copy,
                          compiler_params as tpu_compiler_params)


def _ga_kernel(a_ref, b_ref, o_ref, ctile, ssem, rsem,
               *, axis, n_dev, M_l, tm, fused):
    t = pl.program_id(0)
    nt = pl.num_programs(0)
    me = jax.lax.axis_index(axis)

    ctile[...] = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(ctile.dtype)
    row0 = me * M_l + t * tm
    sync_copy(ctile, o_ref.at[pl.ds(row0, tm)])

    def bcast(src_rows, nrows):
        for off in range(1, n_dev):
            peer = jax.lax.rem(me + off, n_dev)
            pltpu.make_async_remote_copy(
                src_ref=o_ref.at[pl.ds(src_rows, nrows)],
                dst_ref=o_ref.at[pl.ds(src_rows, nrows)],
                send_sem=ssem, recv_sem=rsem, device_id=peer,
                device_id_type=pltpu.DeviceIdType.MESH).start()

    if fused:
        bcast(row0, tm)                      # per-tile, overlaps next tile
    else:
        @pl.when(t == nt - 1)
        def _send_all():
            bcast(me * M_l, M_l)             # one slab per peer, deferred

    @pl.when(t == nt - 1)
    def _drain():
        # wait for all outgoing sends and all peers' incoming tiles
        for off in range(1, n_dev):
            peer = jax.lax.rem(me + off, n_dev)
            src_peer = jax.lax.rem(me - off + n_dev, n_dev)
            if fused:
                for tt in range(nt):
                    out_rows = me * M_l + tt * tm
                    in_rows = src_peer * M_l + tt * tm
                    pltpu.make_async_remote_copy(
                        src_ref=o_ref.at[pl.ds(out_rows, tm)],
                        dst_ref=o_ref.at[pl.ds(out_rows, tm)],
                        send_sem=ssem, recv_sem=rsem, device_id=peer,
                        device_id_type=pltpu.DeviceIdType.MESH).wait_send()
                    pltpu.make_async_remote_copy(
                        src_ref=o_ref.at[pl.ds(in_rows, tm)],
                        dst_ref=o_ref.at[pl.ds(in_rows, tm)],
                        send_sem=ssem, recv_sem=rsem, device_id=peer,
                        device_id_type=pltpu.DeviceIdType.MESH).wait_recv()
            else:
                pltpu.make_async_remote_copy(
                    src_ref=o_ref.at[pl.ds(me * M_l, M_l)],
                    dst_ref=o_ref.at[pl.ds(me * M_l, M_l)],
                    send_sem=ssem, recv_sem=rsem, device_id=peer,
                    device_id_type=pltpu.DeviceIdType.MESH).wait_send()
                pltpu.make_async_remote_copy(
                    src_ref=o_ref.at[pl.ds(src_peer * M_l, M_l)],
                    dst_ref=o_ref.at[pl.ds(src_peer * M_l, M_l)],
                    send_sem=ssem, recv_sem=rsem, device_id=peer,
                    device_id_type=pltpu.DeviceIdType.MESH).wait_recv()


def gemm_allgather_sharded(a, b, *, axis, n_dev, tile_m=128, fused=True,
                           interpret=None):
    """Per-device fn (under shard_map). a: (M_l, K) local; b: (K, N) replicated.
    Returns (n_dev*M_l, N) — the full gathered GEMM output on every device."""
    M_l, K = a.shape
    N = b.shape[1]
    tm = min(tile_m, M_l)
    assert M_l % tm == 0
    kern = functools.partial(_ga_kernel, axis=axis, n_dev=n_dev, M_l=M_l,
                             tm=tm, fused=fused)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        grid=(M_l // tm,),
        in_specs=[
            pl.BlockSpec((tm, K), lambda t: (t, 0)),
            pl.BlockSpec((K, N), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((n_dev * M_l, N), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((tm, N), a.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=11),
    )(a, b)


def gemm_allgather(a_shards, b, mesh, *, axis="x", tile_m=128, fused=True):
    """Global entry: a_shards (n, M_l, K) sharded over axis; b replicated."""
    from jax.sharding import PartitionSpec as P
    n_dev = mesh.shape[axis]

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis), P(None, None)),
                       out_specs=P(axis), check_vma=False)
    def run(a, bb):
        out = gemm_allgather_sharded(a[0], bb, axis=axis, n_dev=n_dev,
                                     tile_m=tile_m, fused=fused)
        return out[None]

    return run(a_shards, b)
