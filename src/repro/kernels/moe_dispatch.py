"""Fused device-initiated MoE dispatch/combine — the DeepEP analogue
(paper §4.3 / Table 3's `PALLAS_RDMA` region of C for the flagship workload).

One Pallas kernel per rank performs the whole MoE step: stage per-expert
token blocks, remote-DMA each block directly into the owning expert's
receive slab (``pltpu.make_async_remote_copy`` — the GIN/RDMA-put analogue),
run the expert FFN per source as its tokens land, and remote-DMA the results
straight back into each source's combine slab. No host round-trip between
phases: a single kernel launch replaces the quantize/dispatch/compute/combine
chain of host-driven builds.

**Tight wire sizes.** Routing here is static per step (``counts`` are trace
time Python ints, identical on every rank), so each edge ``r -> e`` carries
exactly ``counts[e]`` tokens — not the padded max-capacity ``C`` block an
XLA all-to-all would ship. Transfers are quantized into ``block_tokens``-row
microblocks; expert ``e``'s edges need ``b[e] = ceil(counts[e]/B)`` blocks.
The analytic (l3) model credits the exact token counts; the executed
schedule ships the block-rounded ones (see :func:`executed_wire_tokens`).

**Permutation-round schedule.** The legacy pallas interpreter discharges a
remote DMA only when every rank issues it in lockstep and the edges form a
permutation (each rank exactly one incoming copy of one static size). The
trace-time schedule therefore runs rounds ``(off, j)``: in round ``(off,
j)`` rank ``r`` sends microblock ``j`` of its block for expert ``e = (r -
off) % n`` — a shift permutation. ``off = 0`` is the self edge (local
expert's tokens loop back without touching the wire — the self/remote split
of the STREAM_SPLIT build, here inside the kernel). Ranks whose edge has
fewer than ``j+1`` real blocks ship a dummy block into the receiver's trash
row to keep the permutation total; on real TPU hardware (non-interpret)
those slots are elided since lockstep issue is not required. Dummy blocks
are accounted separately and never exceed the padded baseline's wire.

**Completion (design-space K):** ``SIGNAL`` waits per-edge DMA receive
semaphores — expert compute for the earliest-arriving peer starts while
later peers are still in flight (``TILE_PIPELINED``); ``BARRIER`` drains
every edge before any compute (DeepEP-NVL's conservative point).
``contexts`` bounds the in-flight send window (double buffering).

Combine is the exact reverse schedule: rank ``e`` returns ``counts[e]``
processed tokens to every source, shipped bf16/f32 (DeepSeek-V3 quantizes
dispatch only; combine stays high precision).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (LEGACY_INTERPRET, interpret_params, shard_map,
                          compiler_params as tpu_compiler_params)

# ----------------------------------------------------------------- schedule


def block_counts(counts, block_tokens, tight=True):
    """Microblocks per edge into each expert. Padded mode ships the
    max-capacity block count on every edge (the XLA all-to-all shape)."""
    b = [int(math.ceil(c / block_tokens)) for c in counts]
    if not tight:
        b = [max(b)] * len(b)
    return b


@dataclass(frozen=True)
class DispatchSchedule:
    """Trace-time routing schedule + its wire accounting (tokens, per rank).

    ``rounds`` is the lockstep permutation-round list ``[(off, j), ...]``:
    in round ``(off, j)`` rank ``r`` exchanges microblock ``j`` with peer
    ``(r - off) % n`` (dispatch) / ``(r + off) % n`` (combine).
    """
    n: int
    block_tokens: int
    counts: tuple          # exact tokens routed to each expert (per rank)
    blocks: tuple          # microblocks per edge into each expert
    tight: bool

    @property
    def b_max(self):
        return max(self.blocks)

    @property
    def rounds(self):
        return [(off, j) for off in range(self.n)
                for j in range(self.b_max)]

    def wire_tokens(self, rank=0):
        """Exact off-rank tokens rank ``rank`` dispatches (the l3 credit):
        tight = sum(counts) - counts[rank]; padded = C * (n - 1)."""
        if self.tight:
            return int(sum(self.counts)) - int(self.counts[rank])
        return int(max(self.counts)) * (self.n - 1)

    def executed_wire_tokens(self, rank=0):
        """Block-rounded off-rank tokens the kernel actually ships for rank
        ``rank`` (real microblocks only, dummies excluded)."""
        return sum(self.blocks[e] * self.block_tokens
                   for e in range(self.n) if e != rank)

    def dummy_wire_tokens(self, rank=0):
        """Off-rank dummy (trash-row) tokens the lockstep interpreter path
        additionally ships for rank ``rank``; elided on real hardware."""
        return sum((self.b_max - self.blocks[e]) * self.block_tokens
                   for e in range(self.n) if e != rank)


def make_schedule(counts, block_tokens=64, tight=True):
    counts = tuple(int(c) for c in counts)
    return DispatchSchedule(
        n=len(counts), block_tokens=block_tokens, counts=counts,
        blocks=tuple(block_counts(counts, block_tokens, tight)), tight=tight)


# ------------------------------------------------------------------- kernel


def quant_i8(x):
    """int8 wire quantization with per-row scales (shared with the XLA
    builder in workloads/moe_dispatch.py — keep one copy of the formula)."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s


def swiglu_ffn(x, w1, w2):
    """The expert FFN: GEMM1 (2f, gate+up) -> SwiGLU -> GEMM2."""
    g, u = jnp.split(x @ w1, 2, axis=-1)
    return (jax.nn.silu(g) * u) @ w2


def _moe_kernel(x_ref, w1_ref, w2_ref, y_ref,
                send_q, send_s, recv_q, recv_s, ffn_out, comb,
                dsend, drecv, qsend, qrecv, csend, crecv,
                *, axis, sched: DispatchSchedule, offsets, pipelined,
                barrier, contexts, wire_i8):
    n, B = sched.n, sched.block_tokens
    b_max, blocks, counts = sched.b_max, sched.blocks, sched.counts
    stride = b_max * B                       # slab rows per edge region
    trash = n * stride                       # trash row block for dummies
    d_model = x_ref.shape[1]
    me = jax.lax.axis_index(axis)
    def _lookup(table, idx):
        # static-table lookup by traced index without capturing a constant
        # array (the legacy pallas tracer rejects non-scalar kernel consts)
        out = jnp.int32(table[0])
        for k in range(1, n):
            out = jnp.where(idx == k, jnp.int32(table[k]), out)
        return out

    # ---- stage: per-expert token blocks, B-quantized regions, wire dtype
    x = x_ref[...]
    parts = []
    for e in range(n):
        if counts[e] == 0:
            parts.append(jnp.zeros((stride, d_model), x.dtype))
            continue
        blk = jax.lax.dynamic_slice_in_dim(x, offsets[e], counts[e])
        parts.append(jnp.pad(blk, ((0, stride - counts[e]), (0, 0))))
    staged = jnp.concatenate(parts)                    # (n*stride, d)
    if wire_i8:
        q, s = quant_i8(staged)
        send_q[...] = q
        send_s[...] = s
    else:
        send_q[...] = staged
    recv_q[...] = jnp.zeros_like(recv_q)
    if wire_i8:
        recv_s[...] = jnp.ones_like(recv_s)
    comb[...] = jnp.zeros_like(comb)

    # ---- round helpers -------------------------------------------------
    def _dma(src_slab, dst_slab, ssems, rsems, src_off, dst_off, peer,
             src_rank, rows):
        return pltpu.make_async_remote_copy(
            src_ref=src_slab.at[pl.ds(src_off, rows)],
            dst_ref=dst_slab.at[pl.ds(dst_off, rows)],
            send_sem=ssems.at[peer], recv_sem=rsems.at[src_rank],
            device_id=peer, device_id_type=pltpu.DeviceIdType.MESH)

    # The receive-semaphore slot convention is "slot s = edge from source
    # rank s". Under faithful sender-driven RDMA (hardware / the modern
    # InterpretParams simulator) the *sender's* descriptor names the slot
    # its signal lands in on the receiver -> the issuer's own rank (me).
    # The legacy lockstep discharge instead increments the slot named by
    # the *receiver's* own descriptor -> my inbound peer for this round.
    def _sem_slot(inbound_src):
        return inbound_src if LEGACY_INTERPRET else me

    def dispatch_round(off, j):
        """Shift permutation r -> (r - off) % n, microblock j (dispatch)."""
        e = jax.lax.rem(me - off + n, n)               # my receiver
        src = jax.lax.rem(me + off, n)                 # my sender
        real = j < _lookup(blocks, e)
        src_off = jnp.where(real, e * stride + j * B, 0)
        dst_off = jnp.where(real, me * stride + j * B, trash)
        slot = _sem_slot(src)
        cps = [_dma(send_q, recv_q, dsend, drecv, src_off, dst_off, e,
                    slot, B)]
        if wire_i8:
            cps.append(_dma(send_s, recv_s, qsend, qrecv,
                            src_off, dst_off, e, slot, B))
        for cp in cps:
            cp.start()
        return cps

    def combine_round(off, j):
        """Reverse shift r -> (r + off) % n: expert returns tokens."""
        q = jax.lax.rem(me + off, n)                   # my receiver (source)
        src = jax.lax.rem(me - off + n, n)             # my sender (expert)
        real = j < _lookup(blocks, me)                 # I own expert `me`
        src_off = jnp.where(real, q * stride + j * B, 0)
        dst_off = jnp.where(real, me * stride + j * B, trash)
        cp = _dma(ffn_out, comb, csend, crecv, src_off, dst_off, q,
                  _sem_slot(src), B)
        cp.start()
        return [cp]

    def run_rounds(round_fn):
        """Issue all rounds with a bounded in-flight send window."""
        inflight = []
        for off in range(n):
            for j in range(b_max):
                if len(inflight) >= max(1, contexts):
                    for cp in inflight.pop(0):
                        cp.wait_send()
                inflight.append(round_fn(off, j))
        for cps in inflight:
            for cp in cps:
                cp.wait_send()

    blk_elems = B * d_model                            # recv-sem units/block
    scl_elems = B                                      # scale-sem units/block

    def wait_recv_edge(rsems, src, nblocks, elems):
        pltpu.semaphore_wait(rsems.at[src], nblocks * elems)

    def ffn_region(s_idx):
        """Expert FFN over source region s_idx's landed tokens."""
        src = jax.lax.rem(me + s_idx, n)
        rows = recv_q[pl.ds(src * stride, stride)]
        if wire_i8:
            rows = rows.astype(jnp.float32) * recv_s[pl.ds(src * stride,
                                                           stride)]
        h = swiglu_ffn(rows.astype(jnp.float32), w1_ref[...], w2_ref[...])
        valid = (jax.lax.broadcasted_iota(jnp.int32, (stride, 1), 0)
                 < _lookup(counts, me))
        ffn_out.at[pl.ds(src * stride, stride)][...] = jnp.where(
            valid, h, 0.0).astype(ffn_out.dtype)

    # ---- dispatch ------------------------------------------------------
    run_rounds(dispatch_round)

    if barrier or not pipelined:
        # BARRIER / DEFERRED: global rendezvous — drain every edge fully
        # (real + dummy blocks) before any expert compute starts.
        for s_idx in range(n):
            src = jax.lax.rem(me + s_idx, n)
            wait_recv_edge(drecv, src, b_max, blk_elems)
            if wire_i8:
                wait_recv_edge(qrecv, src, b_max, scl_elems)
        for s_idx in range(n):
            ffn_region(s_idx)
    else:
        # SIGNAL + TILE_PIPELINED: consume peers in arrival order — the
        # self edge (s_idx 0) computes first, hiding later dispatch edges
        # behind expert compute; each edge waits only its own semaphore,
        # and its FFN runs immediately, before later edges are fenced.
        for s_idx in range(n):
            src = jax.lax.rem(me + s_idx, n)
            wait_recv_edge(drecv, src, _lookup(blocks, me), blk_elems)
            if wire_i8:
                wait_recv_edge(qrecv, src, _lookup(blocks, me), scl_elems)
            ffn_region(s_idx)
        # drain the dummy-block residue so every semaphore balances
        for s_idx in range(n):
            src = jax.lax.rem(me + s_idx, n)
            wait_recv_edge(drecv, src, b_max - _lookup(blocks, me), blk_elems)
            if wire_i8:
                wait_recv_edge(qrecv, src, b_max - _lookup(blocks, me), scl_elems)

    # ---- combine (reverse path, full precision) ------------------------
    run_rounds(combine_round)
    for s_idx in range(n):
        src = jax.lax.rem(me + s_idx, n)
        wait_recv_edge(crecv, src, b_max, blk_elems)

    # ---- assemble: region e holds my tokens processed by expert e ------
    for e in range(n):
        if counts[e] == 0:
            continue
        y_ref.at[pl.ds(offsets[e], counts[e])][...] = \
            comb[pl.ds(e * stride, counts[e])].astype(y_ref.dtype)


def moe_dispatch_combine_sharded(x, w1, w2, *, axis, sched: DispatchSchedule,
                                 pipelined=True, barrier=False, contexts=2,
                                 wire_i8=False, interpret=None):
    """Per-device fn (under shard_map). x: (T, d) local tokens sorted into
    contiguous per-expert blocks by ``sched.counts``; w1: (d, 2f); w2:
    (f, d) — this rank's expert. Returns (T, d) combined outputs."""
    T, d = x.shape
    n, B, b_max = sched.n, sched.block_tokens, sched.b_max
    assert sum(sched.counts) == T, (sched.counts, T)
    offsets = [0] * n
    for e in range(1, n):
        offsets[e] = offsets[e - 1] + sched.counts[e - 1]
    stride = b_max * B
    slab = n * stride + B                             # + trash block
    wire_dt = jnp.int8 if wire_i8 else x.dtype
    kern = functools.partial(
        _moe_kernel, axis=axis, sched=sched, offsets=offsets,
        pipelined=pipelined, barrier=barrier, contexts=contexts,
        wire_i8=wire_i8)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 3,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((n * stride, d), wire_dt),       # send slab
            pltpu.VMEM((n * stride, 1), jnp.float32),   # send scales
            pltpu.VMEM((slab, d), wire_dt),             # recv slab (+trash)
            pltpu.VMEM((slab, 1), jnp.float32),         # recv scales
            pltpu.VMEM((n * stride, d), jnp.float32),   # expert FFN out
            pltpu.VMEM((slab, d), jnp.float32),         # combine slab
            pltpu.SemaphoreType.DMA((n,)),              # dispatch send
            pltpu.SemaphoreType.DMA((n,)),              # dispatch recv
            pltpu.SemaphoreType.DMA((n,)),              # scale send
            pltpu.SemaphoreType.DMA((n,)),              # scale recv
            pltpu.SemaphoreType.DMA((n,)),              # combine send
            pltpu.SemaphoreType.DMA((n,)),              # combine recv
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=17),
    )(x, w1, w2)


def moe_dispatch_combine(x, w1, w2, mesh, *, axis="x", counts,
                         block_tokens=64, tight=True, pipelined=True,
                         barrier=False, contexts=2, wire_i8=False):
    """Global entry. x: (n, T, d) token-sharded over ``axis`` (each rank's
    rows sorted into contiguous per-expert blocks, identical static
    ``counts`` on every rank); w1: (n, d, 2f), w2: (n, f, d) — expert e's
    weights on rank e. Returns (n, T, d): each rank's tokens after
    dispatch -> expert FFN -> combine."""
    from jax.sharding import PartitionSpec as P
    sched = make_schedule(counts, block_tokens, tight)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(axis), P(axis)),
                       out_specs=P(axis), check_vma=False)
    def run(xs, w1s, w2s):
        out = moe_dispatch_combine_sharded(
            xs[0], w1s[0], w2s[0], axis=axis, sched=sched,
            pipelined=pipelined, barrier=barrier, contexts=contexts,
            wire_i8=wire_i8)
        return out[None]

    return run(x, w1, w2)
