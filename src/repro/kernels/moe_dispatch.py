"""Fused device-initiated MoE dispatch/combine — the DeepEP analogue
(paper §4.3 / Table 3's `PALLAS_RDMA` region of C for the flagship workload).

One Pallas kernel per rank performs the whole MoE step: stage per-expert
token blocks, remote-DMA each block directly into the owning expert's
receive slab (``pltpu.make_async_remote_copy`` — the GIN/RDMA-put analogue),
run the expert FFN per source as its tokens land, and remote-DMA the results
straight back into each source's combine slab. No host round-trip between
phases: a single kernel launch replaces the quantize/dispatch/compute/combine
chain of host-driven builds.

**Tight wire sizes.** Routing here is static per step (``counts`` are trace
time Python ints, identical on every rank), so each edge ``r -> e`` carries
exactly ``counts[e]`` tokens — not the padded max-capacity ``C`` block an
XLA all-to-all would ship. Transfers are quantized into ``block_tokens``-row
microblocks; expert ``e``'s edges need ``b[e] = ceil(counts[e]/B)`` blocks.
The analytic (l3) model credits the exact token counts; the executed
schedule ships the block-rounded ones (see :func:`executed_wire_tokens`).

**Permutation-round schedule.** The legacy pallas interpreter discharges a
remote DMA only when every rank issues it in lockstep and the edges form a
permutation (each rank exactly one incoming copy of one static size). The
trace-time schedule therefore runs rounds ``(off, j)``: in round ``(off,
j)`` rank ``r`` sends microblock ``j`` of its block for expert ``e = (r -
off) % n`` — a shift permutation. ``off = 0`` is the self edge (local
expert's tokens loop back without touching the wire — the self/remote split
of the STREAM_SPLIT build, here inside the kernel). Ranks whose edge has
fewer than ``j+1`` real blocks ship a dummy block into the receiver's trash
row to keep the permutation total; on real TPU hardware (non-interpret)
those slots are elided since lockstep issue is not required. Dummy blocks
are accounted separately and never exceed the padded baseline's wire.

**Completion (design-space K):** ``SIGNAL`` waits per-edge DMA receive
semaphores — expert compute for the earliest-arriving peer starts while
later peers are still in flight (``TILE_PIPELINED``); ``BARRIER`` drains
every edge before any compute (DeepEP-NVL's conservative point); ``COUNTER``
(the FLUX point, ``tile_fused``) consumes dispatch arrivals one microblock
at a time and treats each landed/produced tile as a counter tick.
``contexts`` bounds the in-flight send window (double buffering).

**Tile-fused combine (FLUX / CoCoNet point):** with ``tile_fused`` the
expert FFN runs as a tiled GEMM loop over ``combine_tile``-row tiles and
the combine remote-DMA for each output tile is issued the moment that tile
is ready — instead of finishing the whole per-source FFN before any
combine round. The trace-time round order ``(off, j, t)`` is identical on
every rank and every combine DMA is issued unconditionally (dummy tiles go
to the trash row), so the fused schedule still discharges under the legacy
0.4.x interpreter's lockstep rule.

**Dummy elision (real hardware):** the lockstep permutation padding exists
only for the legacy interpreter's discharge rule. With ``elide_dummy``
(default whenever the kernel is *not* interpreted) dummy-slot DMAs are
predicated away with ``pl.when`` and receive waits count only the real
blocks — the executed wire drops to :meth:`DispatchSchedule.issued_rounds`
real rounds per direction.

Combine is the exact reverse schedule: rank ``e`` returns ``counts[e]``
processed tokens to every source, shipped bf16/f32 (DeepSeek-V3 quantizes
dispatch only; combine stays high precision).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (interpret_params, shard_map, sync_copy,
                          compiler_params as tpu_compiler_params)
# The schedule machinery is defined once, in repro.core.schedule (the
# collective-schedule contract); re-exported here for the kernel's callers.
from repro.core.schedule import (DispatchSchedule, SendWindow,  # noqa: F401
                                 block_counts, make_schedule,
                                 sanitize_combine_tile, sem_slot,
                                 send_window_depths)


# ------------------------------------------------------------------- kernel


def quant_i8(x):
    """int8 wire quantization with per-row scales (shared with the XLA
    builder in workloads/moe_dispatch.py — keep one copy of the formula)."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s


def swiglu_ffn(x, w1, w2):
    """The expert FFN: GEMM1 (2f, gate+up) -> SwiGLU -> GEMM2."""
    g, u = jnp.split(x @ w1, 2, axis=-1)
    return (jax.nn.silu(g) * u) @ w2


def _moe_kernel(*refs, axis, sched: DispatchSchedule, offsets, pipelined,
                barrier, contexts, wire_i8, tile_fused=False,
                combine_tile=None, elide_dummy=False, shared=False,
                probe=None):
    if shared:
        # two-stream serving layout: the shared-expert operands (xs, s1,
        # s2) and output ys ride along, and the shared FFN is issued
        # against the open dispatch send window (see run_rounds)
        (x_ref, w1_ref, w2_ref, xs_ref, s1_ref, s2_ref, y_ref, ys_ref,
         xbuf, w1buf, w2buf, xsbuf, s1buf, s2buf,
         send_q, send_s, recv_q, recv_s, ffn_out, comb,
         dsend, drecv, qsend, qrecv, csend, crecv) = refs
    else:
        (x_ref, w1_ref, w2_ref, y_ref,
         xbuf, w1buf, w2buf,
         send_q, send_s, recv_q, recv_s, ffn_out, comb,
         dsend, drecv, qsend, qrecv, csend, crecv) = refs
        xsbuf = s1buf = s2buf = ys_ref = None
    n, B = sched.n, sched.block_tokens
    b_max, blocks, counts = sched.b_max, sched.blocks, sched.counts
    stride = b_max * B                       # slab rows per edge region
    trash = n * stride                       # trash row block for dummies
    d_model = x_ref.shape[1]
    me = jax.lax.axis_index(axis)

    # GEMM operands live in ANY (HBM): stage them into VMEM before any
    # compute touches them — the interpreter tolerates direct ANY reads
    # but Mosaic on real TPU requires DMA-staged VMEM operands.
    sync_copy(x_ref, xbuf)
    sync_copy(w1_ref, w1buf)
    sync_copy(w2_ref, w2buf)
    if shared:
        sync_copy(xs_ref, xsbuf)
        sync_copy(s1_ref, s1buf)
        sync_copy(s2_ref, s2buf)
    def _lookup(table, idx):
        # static-table lookup by traced index without capturing a constant
        # array (the legacy pallas tracer rejects non-scalar kernel consts)
        out = jnp.int32(table[0])
        for k in range(1, n):
            out = jnp.where(idx == k, jnp.int32(table[k]), out)
        return out

    # ---- stage: per-expert token blocks, B-quantized regions, wire dtype
    x = xbuf[...]
    parts = []
    for e in range(n):
        if counts[e] == 0:
            parts.append(jnp.zeros((stride, d_model), x.dtype))
            continue
        blk = jax.lax.dynamic_slice_in_dim(x, offsets[e], counts[e])
        parts.append(jnp.pad(blk, ((0, stride - counts[e]), (0, 0))))
    staged = jnp.concatenate(parts)                    # (n*stride, d)
    if wire_i8:
        q, s = quant_i8(staged)
        send_q[...] = q
        send_s[...] = s
    else:
        send_q[...] = staged
    recv_q[...] = jnp.zeros_like(recv_q)
    if wire_i8:
        recv_s[...] = jnp.ones_like(recv_s)
    comb[...] = jnp.zeros_like(comb)

    # ---- round helpers -------------------------------------------------
    def _dma(src_slab, dst_slab, ssems, rsems, src_off, dst_off, peer,
             src_rank, rows):
        return pltpu.make_async_remote_copy(
            src_ref=src_slab.at[pl.ds(src_off, rows)],
            dst_ref=dst_slab.at[pl.ds(dst_off, rows)],
            send_sem=ssems.at[peer], recv_sem=rsems.at[src_rank],
            device_id=peer, device_id_type=pltpu.DeviceIdType.MESH)

    # Receive-slot convention routed through the shared contract helper
    # (core/schedule.py::sem_slot): slot s = edge from source rank s,
    # under either the legacy lockstep or the sender-driven engine.
    def _sem_slot(inbound_src):
        return sem_slot(me, inbound_src)

    # With elide_dummy (real hardware — lockstep issue not required) dummy
    # rounds are predicated away entirely: start and wait_send both sit
    # under the same pl.when so the send semaphore stays balanced.
    def _start(real, cps):
        def go():
            for cp in cps:
                cp.start()
        pl.when(real)(go) if elide_dummy else go()

    def _wait_sent(entry):
        real, cps = entry

        def go():
            for cp in cps:
                cp.wait_send()
        pl.when(real)(go) if elide_dummy else go()

    def dispatch_round(off, j):
        """Shift permutation r -> (r - off) % n, microblock j (dispatch)."""
        e = jax.lax.rem(me - off + n, n)               # my receiver
        src = jax.lax.rem(me + off, n)                 # my sender
        real = j < _lookup(blocks, e)
        src_off = jnp.where(real, e * stride + j * B, 0)
        dst_off = jnp.where(real, me * stride + j * B, trash)
        slot = _sem_slot(src)
        cps = [_dma(send_q, recv_q, dsend, drecv, src_off, dst_off, e,
                    slot, B)]
        if wire_i8:
            cps.append(_dma(send_s, recv_s, qsend, qrecv,
                            src_off, dst_off, e, slot, B))
        return real, cps

    def combine_round(off, j, t=0, rows=None):
        """Reverse shift r -> (r + off) % n: expert returns tokens. The
        tile-fused path calls this per ``rows``-row sub-tile ``t``."""
        rows = B if rows is None else rows
        q = jax.lax.rem(me + off, n)                   # my receiver (source)
        src = jax.lax.rem(me - off + n, n)             # my sender (expert)
        real = j < _lookup(blocks, me)                 # I own expert `me`
        rel = j * B + t * rows
        src_off = jnp.where(real, q * stride + rel, 0)
        dst_off = jnp.where(real, me * stride + rel, trash)
        cp = _dma(ffn_out, comb, csend, crecv, src_off, dst_off, q,
                  _sem_slot(src), rows)
        return real, [cp]

    def make_window():
        """The shared contexts-deep send window (schedule.SendWindow) with
        the elide_dummy hooks: a round's start and wait_send both sit under
        the same pl.when(real) so the send semaphore stays balanced."""
        return SendWindow(contexts, start=lambda e: _start(*e),
                          wait=_wait_sent)

    def run_rounds(round_fn, between=None, tag=None):
        """Issue all rounds with a bounded in-flight send window.
        ``between`` runs after the last round is pushed but *before* the
        window drains — compute issued against in-flight sends (the
        two-stream overlap slot). ``tag`` stamps probe marks around it."""
        window = make_window()
        for off in range(n):
            for j in range(b_max):
                window.push(round_fn(off, j))
        if probe is not None and tag:
            probe.mark(f"{tag}_issued")
        if between is not None:
            between()
        window.drain()
        if probe is not None and tag:
            probe.mark(f"{tag}_drained")

    def shared_compute():
        """The second stream: the replicated shared-expert FFN over the
        local tokens, issued while dispatch DMAs are still in flight (the
        TokenWeave overlap — communication hidden behind compute the
        serving step has to do anyway)."""
        if probe is not None:
            probe.mark("shared_ffn")
        ys = swiglu_ffn(xsbuf[...].astype(jnp.float32),
                        s1buf[...], s2buf[...])
        ys_ref.at[pl.ds(0, xsbuf.shape[0])][...] = ys.astype(ys_ref.dtype)

    blk_elems = B * d_model                            # recv-sem units/block
    scl_elems = B                                      # scale-sem units/block

    def wait_recv_edge(rsems, src, nblocks, elems):
        pltpu.semaphore_wait(rsems.at[src], nblocks * elems)

    def ffn_tile(src, rel, rows):
        """Expert FFN over ``rows`` landed tokens at region-relative offset
        ``rel`` of source region ``src`` (one GEMM tile of the fused loop;
        the per-source paths call it once with the whole region)."""
        row0 = src * stride + rel
        blk = recv_q[pl.ds(row0, rows)]
        if wire_i8:
            blk = blk.astype(jnp.float32) * recv_s[pl.ds(row0, rows)]
        h = swiglu_ffn(blk.astype(jnp.float32), w1buf[...], w2buf[...])
        valid = (rel + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
                 < _lookup(counts, me))
        ffn_out.at[pl.ds(row0, rows)][...] = jnp.where(
            valid, h, 0.0).astype(ffn_out.dtype)

    # real blocks on every inbound dispatch edge = my expert's block count
    my_blocks = _lookup(blocks, me)

    # ---- dispatch ------------------------------------------------------
    # (with `shared`, the shared-expert stream runs against the open
    # dispatch send window — before the drain, after the last issue)
    run_rounds(dispatch_round, between=shared_compute if shared else None,
               tag="dispatch")

    if tile_fused:
        # TILE_FUSED + COUNTER (the FLUX point): the expert FFN runs as a
        # tiled GEMM loop and each output tile's combine DMA is issued the
        # moment the tile is ready. Dispatch arrivals are consumed one
        # microblock at a time (counter ticks on the edge semaphore), so
        # the first tile computes while later peers are still in flight —
        # and its combine write goes out before the next tile's GEMM.
        ct = combine_tile          # sanitized by the sharded entry
        window = make_window()
        for off in range(n):
            src = jax.lax.rem(me + off, n)             # source region
            for j in range(b_max):
                real = j < my_blocks

                # dummy rounds are never sent under elide_dummy, so the
                # arrival wait is predicated away like every other elided op
                def arrive(src=src):
                    wait_recv_edge(drecv, src, 1, blk_elems)
                    if wire_i8:
                        wait_recv_edge(qrecv, src, 1, scl_elems)
                pl.when(real)(arrive) if elide_dummy else arrive()
                for t in range(B // ct):
                    # off-interpret, dummy tiles skip the GEMM too — their
                    # combine DMA is elided, so nothing reads the output
                    def tile(rel=j * B + t * ct):
                        ffn_tile(src, rel, ct)
                    pl.when(real)(tile) if elide_dummy else tile()
                    window.push(combine_round(off, j, t, ct))
        window.drain()
    elif barrier or not pipelined:
        # BARRIER / DEFERRED: global rendezvous — drain every edge fully
        # (real + dummy blocks) before any expert compute starts.
        for s_idx in range(n):
            src = jax.lax.rem(me + s_idx, n)
            nb = my_blocks if elide_dummy else b_max
            wait_recv_edge(drecv, src, nb, blk_elems)
            if wire_i8:
                wait_recv_edge(qrecv, src, nb, scl_elems)
        for s_idx in range(n):
            ffn_tile(jax.lax.rem(me + s_idx, n), 0, stride)
    else:
        # SIGNAL + TILE_PIPELINED: consume peers in arrival order — the
        # self edge (s_idx 0) computes first, hiding later dispatch edges
        # behind expert compute; each edge waits only its own semaphore,
        # and its FFN runs immediately, before later edges are fenced.
        for s_idx in range(n):
            src = jax.lax.rem(me + s_idx, n)
            wait_recv_edge(drecv, src, my_blocks, blk_elems)
            if wire_i8:
                wait_recv_edge(qrecv, src, my_blocks, scl_elems)
            ffn_tile(src, 0, stride)
        if not elide_dummy:
            # drain the dummy-block residue so every semaphore balances
            for s_idx in range(n):
                src = jax.lax.rem(me + s_idx, n)
                wait_recv_edge(drecv, src, b_max - my_blocks, blk_elems)
                if wire_i8:
                    wait_recv_edge(qrecv, src, b_max - my_blocks, scl_elems)

    # ---- combine (reverse path, full precision) ------------------------
    if not tile_fused:
        run_rounds(combine_round)
    for s_idx in range(n):
        src = jax.lax.rem(me + s_idx, n)
        nb = _lookup(blocks, src) if elide_dummy else b_max
        wait_recv_edge(crecv, src, nb, blk_elems)

    # ---- assemble: region e holds my tokens processed by expert e ------
    for e in range(n):
        if counts[e] == 0:
            continue
        y_ref.at[pl.ds(offsets[e], counts[e])][...] = \
            comb[pl.ds(e * stride, counts[e])].astype(y_ref.dtype)


def moe_dispatch_combine_sharded(x, w1, w2, *, axis, sched: DispatchSchedule,
                                 pipelined=True, barrier=False, contexts=2,
                                 wire_i8=False, tile_fused=False,
                                 combine_tile=None, elide_dummy=None,
                                 interpret=None, shared=None, probe=None):
    """Per-device fn (under shard_map). x: (T, d) local tokens sorted into
    contiguous per-expert blocks by ``sched.counts``; w1: (d, 2f); w2:
    (f, d) — this rank's expert. Returns (T, d) combined outputs.

    ``shared=(xs, s1, s2)`` enables the two-stream serving path: xs (Ts, d)
    local tokens, s1 (d, 2fs) / s2 (fs, d) the replicated shared-expert
    weights. The shared FFN is issued inside the kernel against the open
    dispatch send window and the call returns ``(y, ys)``. ``probe`` (a
    :class:`~repro.core.trace.ScheduleProbe`) records interleave marks."""
    T, d = x.shape
    n, B, b_max = sched.n, sched.block_tokens, sched.b_max
    assert sum(sched.counts) == T, (sched.counts, T)
    assert not (tile_fused and barrier), \
        "tile_fused (COUNTER completion) excludes a BARRIER rendezvous"
    offsets = [0] * n
    for e in range(1, n):
        offsets[e] = offsets[e - 1] + sched.counts[e - 1]
    stride = b_max * B
    slab = n * stride + B                             # + trash block
    wire_dt = jnp.int8 if wire_i8 else x.dtype
    ip = interpret if interpret is not None else interpret_params()
    if elide_dummy is None:
        # the lockstep permutation padding is only needed by the
        # interpreter's discharge rule; compiled TPU builds skip it
        elide_dummy = not ip
    kern = functools.partial(
        _moe_kernel, axis=axis, sched=sched, offsets=offsets,
        pipelined=pipelined, barrier=barrier, contexts=contexts,
        wire_i8=wire_i8, tile_fused=tile_fused,
        combine_tile=sanitize_combine_tile(combine_tile, B),
        elide_dummy=elide_dummy, shared=shared is not None, probe=probe)
    inputs = (x, w1, w2)
    out_shape = jax.ShapeDtypeStruct((T, d), x.dtype)
    out_specs = pl.BlockSpec(memory_space=pl.ANY)
    stage_scratch = [
        pltpu.VMEM((T, d), x.dtype),                    # staged x operand
        pltpu.VMEM(w1.shape, w1.dtype),                 # staged w1 operand
        pltpu.VMEM(w2.shape, w2.dtype),                 # staged w2 operand
    ]
    if shared is not None:
        xs, s1, s2 = shared
        inputs = (x, w1, w2, xs, s1, s2)
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct(xs.shape, x.dtype))
        out_specs = (out_specs, pl.BlockSpec(memory_space=pl.ANY))
        stage_scratch += [
            pltpu.VMEM(xs.shape, xs.dtype),             # staged shared x
            pltpu.VMEM(s1.shape, s1.dtype),             # staged shared w1
            pltpu.VMEM(s2.shape, s2.dtype),             # staged shared w2
        ]
    return pl.pallas_call(
        kern,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * len(inputs),
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=stage_scratch + [
            pltpu.VMEM((n * stride, d), wire_dt),       # send slab
            pltpu.VMEM((n * stride, 1), jnp.float32),   # send scales
            pltpu.VMEM((slab, d), wire_dt),             # recv slab (+trash)
            pltpu.VMEM((slab, 1), jnp.float32),         # recv scales
            pltpu.VMEM((n * stride, d), jnp.float32),   # expert FFN out
            pltpu.VMEM((slab, d), jnp.float32),         # combine slab
            pltpu.SemaphoreType.DMA((n,)),              # dispatch send
            pltpu.SemaphoreType.DMA((n,)),              # dispatch recv
            pltpu.SemaphoreType.DMA((n,)),              # scale send
            pltpu.SemaphoreType.DMA((n,)),              # scale recv
            pltpu.SemaphoreType.DMA((n,)),              # combine send
            pltpu.SemaphoreType.DMA((n,)),              # combine recv
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=17),
    )(*inputs)


def moe_dispatch_combine(x, w1, w2, mesh, *, axis="x", counts,
                         block_tokens=64, tight=True, pipelined=True,
                         barrier=False, contexts=2, wire_i8=False,
                         tile_fused=False, combine_tile=None,
                         elide_dummy=None, shared=None, probe=None):
    """Global entry. x: (n, T, d) token-sharded over ``axis`` (each rank's
    rows sorted into contiguous per-expert blocks, identical static
    ``counts`` on every rank); w1: (n, d, 2f), w2: (n, f, d) — expert e's
    weights on rank e. Returns (n, T, d): each rank's tokens after
    dispatch -> expert FFN -> combine.

    ``shared=(xs, s1, s2)`` — xs (n, Ts, d) token-sharded, s1 (d, 2fs) /
    s2 (fs, d) replicated shared-expert weights — returns ``(y, ys)``
    with ys (n, Ts, d) the shared-expert stream computed inside the
    kernel against the dispatch send window (the TokenWeave two-stream
    serving point)."""
    from jax.sharding import PartitionSpec as P
    sched = make_schedule(counts, block_tokens, tight)

    if shared is None:
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(axis), P(axis), P(axis)),
                           out_specs=P(axis), check_vma=False)
        def run(xs_, w1s, w2s):
            out = moe_dispatch_combine_sharded(
                xs_[0], w1s[0], w2s[0], axis=axis, sched=sched,
                pipelined=pipelined, barrier=barrier, contexts=contexts,
                wire_i8=wire_i8, tile_fused=tile_fused,
                combine_tile=combine_tile, elide_dummy=elide_dummy,
                probe=probe)
            return out[None]

        return run(x, w1, w2)

    xs, s1, s2 = shared

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(axis), P(axis)), check_vma=False)
    def run2(xs_, w1s, w2s, xss, s1r, s2r):
        y, ys = moe_dispatch_combine_sharded(
            xs_[0], w1s[0], w2s[0], axis=axis, sched=sched,
            pipelined=pipelined, barrier=barrier, contexts=contexts,
            wire_i8=wire_i8, tile_fused=tile_fused,
            combine_tile=combine_tile, elide_dummy=elide_dummy,
            shared=(xss[0], s1r, s2r), probe=probe)
        return y[None], ys[None]

    return run2(x, w1, w2, xs, s1, s2)
