"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True):
    """q/k/v: (BH, S, hd)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Skv = s.shape[-2:]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ring_attention_ref(q, k, v, *, causal=True):
    """Global oracle for ring attention: q/k/v (n_dev, BH, S_l, hd) stacked
    per device -> same layout output. Equivalent to full attention over the
    concatenated sequence."""
    n, BH, Sl, hd = q.shape
    qf = q.transpose(1, 0, 2, 3).reshape(BH, n * Sl, hd)
    kf = k.transpose(1, 0, 2, 3).reshape(BH, n * Sl, hd)
    vf = v.transpose(1, 0, 2, 3).reshape(BH, n * Sl, hd)
    o = flash_attention_ref(qf, kf, vf, causal=causal)
    return o.reshape(BH, n, Sl, hd).transpose(1, 0, 2, 3)


def gemm_allgather_ref(a_shards, b):
    """a_shards: (n_dev, M_l, K); b: (K, N) -> (n_dev, n_dev*M_l, N):
    every device ends with the full concatenated GEMM output."""
    c = jnp.einsum("nmk,kn2->nmn2".replace("n2", "p"), a_shards, b)
    full = c.reshape(-1, b.shape[1])
    n = a_shards.shape[0]
    return jnp.broadcast_to(full[None], (n,) + full.shape)


def kv_shuttle_ref(x, wk, wv):
    """Prefill rank computes K = x@wk, V = x@wv; decode rank receives both."""
    return x @ wk, x @ wv
