"""Fused ring flash-attention with device-initiated KV rotation
(the paper's Flash Attention + Context Parallelism workload, §4.2/App. N,
adapted to TPU Pallas remote DMA) — realized against the shared
collective-schedule contract (``repro.core.schedule.RingSchedule``).

Each device owns one Q shard; KV shards rotate around the ring INSIDE the
kernel via ``pltpu.make_async_remote_copy`` (the GIN-put analogue). The
kernel is a full trace-time unroll of the schedule's lockstep
``(step, chunk)`` rounds — in rotation step ``s`` every rank ships the KV
shard it currently holds one hop forward (rank ``r`` → ``(r+1) % n``, a
shift permutation the legacy 0.4.x interpreter discharges in lockstep),
split into ``kv_chunk``-row chunks staged in chunk-major VMEM double
buffers.

Placement realizations (design-space P), all driven by the one schedule:

  TILE_FUSED (+COUNTER = the FLUX point for rings) — chunk-major rounds:
    chunk ``c``'s onward send issues the moment its arrival tick clears,
    and the attention contribution of chunk ``c`` computes while chunk
    ``c+1``'s rotation is still in flight. Per-chunk receive semaphores
    tick arrivals off one chunk at a time; a ``contexts``-deep send window
    bounds the in-flight chunk sends (replacing the old kernel's
    eager/lazy-fence special cases). SIGNAL completion keeps the chunked
    sends but drains all of a step's arrivals up front.
  TILE_PIPELINED — one whole-shard round per step, issued at the top of
    the round and fenced only after the round's compute (lazy fence:
    transfer overlaps compute).
  DEFERRED — the whole-shard round is awaited immediately (sequential
    comm/compute, the host-driven shape inside one kernel). ACQREL
    ordering forces the same eager fence on the pipelined path.

Slot-reuse backpressure: step ``s``'s send writes the neighbour slot its
step ``s-1`` compute read — the sender waits the downstream free-slot
credit before issuing (``remote_semaphore_signal`` ACK after the consumer
drains; degenerates to local bookkeeping under the legacy interpreter).

Every DMA is issued unconditionally in the schedule's total order (the
lockstep discharge rule); no ``pl.when`` wraps any ``dma.start()``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import (interpret_params, remote_semaphore_signal,
                          shard_map, sync_copy,
                          compiler_params as tpu_compiler_params)
from repro.core.schedule import (RingSchedule, SendWindow,  # noqa: F401
                                 make_ring_schedule, sanitize_kv_chunk)

NEG_INF = -1e30


def _ring_kernel(q_ref, k_ref, v_ref, o_ref, kbuf, vbuf,
                 ksend, krecv, vsend, vrecv, credit,
                 *, axis, sched: RingSchedule, causal, scale, counter,
                 pipelined, eager_wait, contexts):
    n, nc, cr = sched.n, sched.nc, sched.kv_chunk
    fused = sched.fused
    BH, Sl, hd = q_ref.shape
    me = jax.lax.axis_index(axis)
    nxt = jax.lax.rem(me + 1, n)
    prv = jax.lax.rem(me - 1 + n, n)
    chunk_elems = BH * cr * hd

    # local KV shard -> double-buffer slot 0 (k_ref/v_ref arrive chunk-major
    # (nc, BH, cr, hd) from the sharded entry; kbuf rows [slot*nc + c])
    for c in range(nc):
        sync_copy(k_ref.at[c], kbuf.at[c])
        sync_copy(v_ref.at[c], vbuf.at[c])

    q = q_ref[...].astype(jnp.float32)                 # (BH, Sl, hd)
    acc = jnp.zeros((BH, Sl, hd), jnp.float32)
    m_i = jnp.full((BH, Sl), NEG_INF, jnp.float32)
    l_i = jnp.zeros((BH, Sl), jnp.float32)

    def chunk_dma(buf, ssem, rsem_slot, src_chunk, dst_chunk, nchunks):
        """Ship kbuf/vbuf chunks [src_chunk, src_chunk+nchunks) one hop
        forward into the neighbour's matching slot — a shift permutation."""
        return pltpu.make_async_remote_copy(
            src_ref=buf.at[pl.ds(src_chunk, nchunks)],
            dst_ref=buf.at[pl.ds(dst_chunk, nchunks)],
            send_sem=ssem, recv_sem=rsem_slot,
            device_id=nxt, device_id_type=pltpu.DeviceIdType.MESH)

    # contexts-deep send window over the trace-time round order (the shared
    # schedule.SendWindow — a round's K/V pair counts as ONE entry): every
    # DMA is issued unconditionally (lockstep rule), the window only bounds
    # how many rounds' send semaphores stay unawaited. Drained at each step
    # boundary (the slot-credit handshake needs the step's sends retired).
    window = SendWindow(contexts)

    def issue(slot, c, nchunks):
        kd = chunk_dma(kbuf, ksend, krecv.at[c], slot * nc + c,
                       (1 - slot) * nc + c, nchunks)
        vd = chunk_dma(vbuf, vsend, vrecv.at[c], slot * nc + c,
                       (1 - slot) * nc + c, nchunks)
        window.push([kd, vd])

    def tick(c, nchunks):
        """Receive-side readiness: chunk c of the in-flight rotation
        landed (COUNTER consumes these one chunk at a time)."""
        pltpu.semaphore_wait(krecv.at[c], nchunks * chunk_elems)
        pltpu.semaphore_wait(vrecv.at[c], nchunks * chunk_elems)

    def attend(s, c, acc, m_i, l_i):
        """Flash-accumulate the attention contribution of chunk ``c`` of
        the shard held at step ``s`` (originating rank (me - s) % n)."""
        slot = s % 2
        k_c = kbuf[slot * nc + c].astype(jnp.float32)  # (BH, cr, hd)
        v_c = vbuf[slot * nc + c].astype(jnp.float32)
        s_mat = jax.lax.dot_general(
            q, k_c, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale  # (BH, Sl, cr)
        if causal:
            src_dev = jax.lax.rem(me - s + n, n)
            qpos = me * Sl + jax.lax.broadcasted_iota(
                jnp.int32, s_mat.shape, 1)
            kpos = src_dev * Sl + c * cr + jax.lax.broadcasted_iota(
                jnp.int32, s_mat.shape, 2)
            s_mat = jnp.where(qpos >= kpos, s_mat, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s_mat, axis=2))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s_mat - m_new[:, :, None])
        l_i = l_i * alpha + jnp.sum(p, axis=2)
        acc = acc * alpha[:, :, None] + jax.lax.dot_general(
            p, v_c, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_i

    for s in range(n):                       # n compute rounds, n-1 rotations
        slot = s % 2
        rotate = s <= n - 2                  # step s ships slot s%2 onward
        if rotate and s >= 1:
            # step s's send overwrites the neighbour slot its step s-1
            # compute read: wait the downstream free-slot credit first
            pltpu.semaphore_wait(credit, 1)
        if fused:
            if not counter and s >= 1:
                # SIGNAL: drain the whole step's arrivals up front
                for c in range(nc):
                    tick(c, 1)
            for c in range(nc):
                if counter and s >= 1:
                    tick(c, 1)               # consume chunk c's arrival ...
                if rotate:
                    issue(slot, c, 1)        # ... ship it onward (windowed)
                acc, m_i, l_i = attend(s, c, acc, m_i, l_i)
            window.drain()
        else:
            if rotate:
                issue(slot, 0, nc)           # one whole-shard round
                if eager_wait or not pipelined:
                    window.drain()           # DEFERRED/ACQREL: fully fenced
                    tick(0, nc)
            for c in range(nc):
                acc, m_i, l_i = attend(s, c, acc, m_i, l_i)
            if rotate and pipelined and not eager_wait:
                window.drain()           # lazy fence: after the compute
                tick(0, nc)
        if s <= n - 3:
            # slot s%2 fully consumed (compute done, outgoing sends
            # retired): upstream's next-next send may reuse it
            remote_semaphore_signal(credit, 1, device_id=prv,
                                    device_id_type=pltpu.DeviceIdType.MESH)

    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, :, None]
                  ).astype(o_ref.dtype)


def ring_attention_sharded(q, k, v, *, axis, n_dev, causal=True,
                           sched: RingSchedule = None, kv_chunk=None,
                           fused=False, counter=False, pipelined=True,
                           eager_wait=False, contexts=2, interpret=None):
    """Per-device fn (call under shard_map). q/k/v: (BH, Sl, hd) local.
    An explicit ``sched`` takes precedence: the ``kv_chunk``/``fused``
    knobs are consulted only to build one when ``sched`` is None."""
    BH, Sl, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    if sched is None:
        sched = make_ring_schedule(n_dev, Sl, kv_chunk or Sl, fused)
    assert sched.n == n_dev and sched.rows == Sl, (sched, n_dev, Sl)
    nc, cr = sched.nc, sched.kv_chunk
    # chunk-major staging: the kernel's KV buffers (and rotation DMAs)
    # address whole chunks through a single leading index
    kc = k.reshape(BH, nc, cr, hd).swapaxes(0, 1)
    vc = v.reshape(BH, nc, cr, hd).swapaxes(0, 1)
    kern = functools.partial(_ring_kernel, axis=axis, sched=sched,
                             causal=causal, scale=scale, counter=counter,
                             pipelined=pipelined, eager_wait=eager_wait,
                             contexts=contexts)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec((BH, Sl, hd), lambda: (0, 0, 0)),  # q in VMEM
            pl.BlockSpec(memory_space=pl.ANY),              # k chunks (HBM)
            pl.BlockSpec(memory_space=pl.ANY),              # v chunks (HBM)
        ],
        out_specs=pl.BlockSpec((BH, Sl, hd), lambda: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sl, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((2 * nc, BH, cr, hd), q.dtype),  # K double buffer
            pltpu.VMEM((2 * nc, BH, cr, hd), q.dtype),  # V double buffer
            pltpu.SemaphoreType.DMA,                    # k send
            pltpu.SemaphoreType.DMA((nc,)),             # k per-chunk recv
            pltpu.SemaphoreType.DMA,                    # v send
            pltpu.SemaphoreType.DMA((nc,)),             # v per-chunk recv
            pltpu.SemaphoreType.REGULAR,                # free-slot credit
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=7),
    )(q, kc, vc)


def ring_attention(q, k, v, mesh, *, axis="x", causal=True, kv_chunk=None,
                   fused=False, counter=False, pipelined=True,
                   eager_wait=False, contexts=2):
    """Global entry: q/k/v (n_dev, BH, Sl, hd) sharded on dim 0 over `axis`.
    ``fused``+``counter`` selects the chunk-rotating FLUX-ring path
    (``kv_chunk`` rows per rotation round, sanitized to a divisor of Sl)."""
    from jax.sharding import PartitionSpec as P
    n_dev = mesh.shape[axis]
    sched = make_ring_schedule(n_dev, q.shape[2],
                               kv_chunk or (q.shape[2] if not fused else 64),
                               fused)

    @functools.partial(shard_map, mesh=mesh, in_specs=P(axis),
                       out_specs=P(axis), check_vma=False)
    def run(qs, ks, vs):
        out = ring_attention_sharded(qs[0], ks[0], vs[0], axis=axis,
                                     n_dev=n_dev, causal=causal, sched=sched,
                                     counter=counter, pipelined=pipelined,
                                     eager_wait=eager_wait,
                                     contexts=contexts)
        return out[None]

    return run(q, k, v)
