"""Fused ring flash-attention with device-initiated KV rotation
(the paper's Flash Attention + Context Parallelism workload, §4.2/App. N,
adapted to TPU Pallas remote DMA).

Each device owns one Q shard; KV shards rotate around the ring INSIDE the
kernel via ``pltpu.make_async_remote_copy`` (the GIN-put analogue) with DMA
semaphores (signal completion). The grid is (rounds, BH): rounds are
sequential on TPU, so the double-buffered VMEM KV slots and the f32
accumulators persist across rounds.

Placement realizations (design-space P):
  TILE_PIPELINED — the send of the *current* KV block to the neighbour is
    started at the top of round r (both source slot read-only for compute),
    and the recv wait happens only at the start of round r+1: transfer fully
    overlaps this round's attention compute.
  DEFERRED      — the send is issued after the round's compute finishes and
    is waited on immediately (sequential comm/compute — the fast-path
    conservative shape, matching host-driven behaviour inside one kernel).

Ordering realizations (O): ACQREL waits eagerly right after issuing (fully
fenced), ACQUIRE/RELEASE/RELAXED defer the recv wait to the consuming round.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import (interpret_params, remote_semaphore_signal,
                          shard_map, sync_copy,
                          compiler_params as tpu_compiler_params)

NEG_INF = -1e30


def _ring_kernel(q_ref, k_ref, v_ref, o_ref,
                 kbuf, vbuf, acc, m_i, l_i,
                 ksend, krecv, vsend, vrecv, credit,
                 *, axis, causal, scale, pipelined, eager_wait, n_dev):
    r = pl.program_id(0)
    bh = pl.program_id(1)
    n_bh = pl.num_programs(1)
    me = jax.lax.axis_index(axis)
    nxt = jax.lax.rem(me + 1, n_dev)
    prv = jax.lax.rem(me - 1 + n_dev, n_dev)
    cur = jax.lax.rem(r, 2)
    sl = q_ref.shape[1]

    @pl.when((r == 0) & (bh == 0))
    def _load_local():
        # round 0 uses the local KV shard: copy HBM -> VMEM slot 0
        sync_copy(k_ref, kbuf.at[0])
        sync_copy(v_ref, vbuf.at[0])

    def _descs(slot_src, slot_dst):
        kd = pltpu.make_async_remote_copy(
            src_ref=kbuf.at[slot_src], dst_ref=kbuf.at[slot_dst],
            send_sem=ksend, recv_sem=krecv, device_id=nxt,
            device_id_type=pltpu.DeviceIdType.MESH)
        vd = pltpu.make_async_remote_copy(
            src_ref=vbuf.at[slot_src], dst_ref=vbuf.at[slot_dst],
            send_sem=vsend, recv_sem=vrecv, device_id=nxt,
            device_id_type=pltpu.DeviceIdType.MESH)
        return kd, vd

    def _send(slot_src, slot_dst):
        kd, vd = _descs(slot_src, slot_dst)
        kd.start()
        vd.start()

    def _wait(slot_src, slot_dst):
        kd, vd = _descs(slot_src, slot_dst)   # same sems/shapes: legal waiter
        kd.wait()
        vd.wait()

    # Rotation is always issued at the top of the round. TILE_PIPELINED
    # defers the recv fence to the end of the round so the transfer overlaps
    # this round's attention compute; DEFERRED (and eager orderings) wait
    # immediately — zero overlap, comm strictly between compute rounds, the
    # host-driven sequential shape. (Issuing the send *after* the compute
    # block instead trips an XLA:CPU reshape bug on the legacy-interpreter
    # lowering path, and is behaviourally identical for the zero-overlap
    # realizations.)
    # Backpressure: round r's send writes the neighbour slot its round
    # r-1 compute read — wait for the neighbour's free-slot credit first.
    @pl.when((bh == 0) & (r < n_dev - 1))
    def _rotate():
        @pl.when(r >= 1)
        def _backpressure():
            pltpu.semaphore_wait(credit, 1)
        _send(cur, jax.lax.rem(r + 1, 2))
        if eager_wait or not pipelined:
            _wait(cur, jax.lax.rem(r + 1, 2))

    # ---- compute this round's attention tile (flash accumulate) ----
    @pl.when(r == 0)
    def _init():
        acc[bh] = jnp.zeros_like(acc[bh])
        m_i[bh] = jnp.full_like(m_i[bh], NEG_INF)
        l_i[bh] = jnp.zeros_like(l_i[bh])

    src_dev = jax.lax.rem(me - r + n_dev, n_dev)     # whose KV we hold now
    q = q_ref[bh].astype(jnp.float32)                # (Sl, hd)
    k = kbuf[cur, bh].astype(jnp.float32)
    v = vbuf[cur, bh].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = me * sl + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = src_dev * sl + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_i[bh]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_i[bh] = l_i[bh] * alpha + jnp.sum(p, axis=1)
    acc[bh] = acc[bh] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_i[bh] = m_new

    if pipelined and not eager_wait:
        # lazy ordering: block round r+1 until the rotated KV landed
        @pl.when((bh == n_bh - 1) & (r < n_dev - 1))
        def _fence():
            _wait(cur, jax.lax.rem(r + 1, 2))

    # Compute on slot r%2 is done AND our outgoing DMA reading it has been
    # waited (the fence above ran): tell the upstream device its next-next
    # send may now reuse this slot. Must come after the waits — an ACK before
    # wait_send would let upstream overwrite a slot our DMA is still reading.
    @pl.when((bh == n_bh - 1) & (r <= n_dev - 3))
    def _ack_upstream():
        remote_semaphore_signal(credit, 1, device_id=prv,
                                device_id_type=pltpu.DeviceIdType.MESH)

    @pl.when(r == n_dev - 1)
    def _finish():
        o_ref[bh] = (acc[bh] / jnp.maximum(l_i[bh], 1e-30)[:, None]
                     ).astype(o_ref.dtype)


def ring_attention_sharded(q, k, v, *, axis, n_dev, causal=True,
                           pipelined=True, eager_wait=False, interpret=None):
    """Per-device fn (call under shard_map). q/k/v: (BH, Sl, hd) local."""
    BH, Sl, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    kern = functools.partial(_ring_kernel, axis=axis, causal=causal,
                             scale=scale, pipelined=pipelined,
                             eager_wait=eager_wait, n_dev=n_dev)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        grid=(n_dev, BH),
        in_specs=[
            pl.BlockSpec((BH, Sl, hd), lambda r, bh: (0, 0, 0)),  # q in VMEM
            pl.BlockSpec(memory_space=pl.ANY),                 # k (HBM)
            pl.BlockSpec(memory_space=pl.ANY),                 # v (HBM)
        ],
        out_specs=pl.BlockSpec((BH, Sl, hd), lambda r, bh: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sl, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, BH, Sl, hd), q.dtype),    # K double buffer
            pltpu.VMEM((2, BH, Sl, hd), q.dtype),    # V double buffer
            pltpu.VMEM((BH, Sl, hd), jnp.float32),   # acc
            pltpu.VMEM((BH, Sl), jnp.float32),       # m
            pltpu.VMEM((BH, Sl), jnp.float32),       # l
            pltpu.SemaphoreType.DMA,                 # k send
            pltpu.SemaphoreType.DMA,                 # k recv
            pltpu.SemaphoreType.DMA,                 # v send
            pltpu.SemaphoreType.DMA,                 # v recv
            pltpu.SemaphoreType.REGULAR,             # free-slot credit
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=7),
    )(q, k, v)


def ring_attention(q, k, v, mesh, *, axis="x", causal=True, pipelined=True,
                   eager_wait=False):
    """Global entry: q/k/v (n_dev, BH, Sl, hd) sharded on dim 0 over `axis`."""
    from jax.sharding import PartitionSpec as P
    n_dev = mesh.shape[axis]

    @functools.partial(shard_map, mesh=mesh, in_specs=P(axis),
                       out_specs=P(axis), check_vma=False)
    def run(qs, ks, vs):
        out = ring_attention_sharded(qs[0], ks[0], vs[0], axis=axis,
                                     n_dev=n_dev, causal=causal,
                                     pipelined=pipelined,
                                     eager_wait=eager_wait)
        return out[None]

    return run(q, k, v)
