"""KV-cache shuttle: chained GPU-triggered sends for disaggregated
prefill->decode serving (paper workload 3, Table 4 row 3).

The prefill rank computes K = x@Wk, starts its send, computes V = x@Wv while
K is on the wire, then sends V (signal-chained). The decode rank waits
entirely on-device. The CUCo-discovered strategy is exactly this chain
("K GEMM -> send K -> V GEMM -> send V with signal"); the host-driven
baseline computes both projections, then transfers both (idle network during
compute, idle compute during transfer).

``chained=False`` reproduces the sequential shape inside the kernel:
each send is awaited before the next GEMM starts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import (LEGACY_INTERPRET, interpret_params, shard_map,
                          compiler_params as tpu_compiler_params)


def _shuttle_kernel(x_ref, wk_ref, wv_ref, ko_ref, vo_ref,
                    kbuf, vbuf, ksem, krecv, vsem, vrecv,
                    *, axis, chained, decode_rank):
    me = jax.lax.axis_index(axis)

    def kdma():
        return pltpu.make_async_remote_copy(
            src_ref=kbuf, dst_ref=ko_ref, send_sem=ksem, recv_sem=krecv,
            device_id=decode_rank, device_id_type=pltpu.DeviceIdType.MESH)

    def vdma():
        return pltpu.make_async_remote_copy(
            src_ref=vbuf, dst_ref=vo_ref, send_sem=vsem, recv_sem=vrecv,
            device_id=decode_rank, device_id_type=pltpu.DeviceIdType.MESH)

    def _prefill():
        kbuf[...] = jax.lax.dot_general(
            x_ref[...], wk_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(kbuf.dtype)
        kd = kdma()
        kd.start()                       # K on the wire ...
        if not chained:
            kd.wait_send()               # sequential: drain before V GEMM
        vbuf[...] = jax.lax.dot_general(
            x_ref[...], wv_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(vbuf.dtype)
        vd = vdma()
        vd.start()
        if chained:
            kd.wait_send()
        vd.wait_send()

    def _decode():
        kdma().wait_recv()
        vdma().wait_recv()

    if LEGACY_INTERPRET:
        # The legacy interpreter discharges a remote DMA via an all_gather
        # every rank must reach — role-predicated issue would deadlock. Run
        # the full chain on BOTH ranks in lockstep: the decode rank's
        # outgoing copy carries its (zero) projections but the discharge
        # selects the prefill rank as source for the decode rank, and the
        # prefill rank's spurious self-delivery is masked by the caller
        # (outputs are only valid on the decode rank by contract).
        _prefill()
        _decode()
    else:
        pl.when(me != decode_rank)(_prefill)
        pl.when(me == decode_rank)(_decode)


def kv_shuttle_sharded(x, wk, wv, *, axis, chained=True, decode_rank=1,
                       interpret=None):
    """Per-device fn (under shard_map over a 2-rank axis).
    x: (T, d); wk/wv: (d, dk). Returns (K, V) — valid on the decode rank."""
    T, d = x.shape
    dk = wk.shape[1]
    kern = functools.partial(_shuttle_kernel, axis=axis, chained=chained,
                             decode_rank=decode_rank)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec((T, d), lambda: (0, 0)),
            pl.BlockSpec((d, dk), lambda: (0, 0)),
            pl.BlockSpec((d, dk), lambda: (0, 0)),
        ],
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_shape=[jax.ShapeDtypeStruct((T, dk), x.dtype)] * 2,
        scratch_shapes=[
            pltpu.VMEM((T, dk), x.dtype),
            pltpu.VMEM((T, dk), x.dtype),
            pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=13),
    )(x, wk, wv)


def kv_shuttle(x, wk, wv, mesh, *, axis="x", chained=True):
    """Global entry. x: (2, T, d) sharded over the 2-rank axis (prefill rank
    holds real activations); wk/wv replicated. Returns K/V gathered per rank
    — row [1] (decode rank) holds the shuttled projections."""
    from jax.sharding import PartitionSpec as P

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None, None), P(None, None)),
                       out_specs=(P(axis), P(axis)), check_vma=False)
    def run(xs, k, v):
        ko, vo = kv_shuttle_sharded(xs[0], k, v, axis=axis, chained=chained)
        # the prefill rank never writes its own output buffers: zero them
        me = jax.lax.axis_index(axis)
        ko = jnp.where(me == 1, ko, 0.0)
        vo = jnp.where(me == 1, vo, 0.0)
        return ko[None], vo[None]

    return run(x, wk, wv)
