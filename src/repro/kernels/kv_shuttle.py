"""KV-cache shuttle: chained GPU-triggered sends for disaggregated
prefill->decode serving (paper workload 3, Table 4 row 3) — realized
against the shared collective-schedule contract
(``repro.core.schedule.RingSchedule``, the ``n = 2`` degenerate ring:
one rotation step, prefill → decode).

The prefill rank computes K = x@Wk, starts its send, computes V = x@Wv
while K is on the wire, then sends V (signal-chained). The decode rank
waits entirely on-device. The CUCo-discovered strategy is exactly this
chain ("K GEMM -> send K -> V GEMM -> send V with signal"); the
host-driven baseline computes both projections, then transfers both.

Realizations, all driven by the one schedule:

  TILE_FUSED (+COUNTER = the FLUX point) — chunk-major rounds: the K/V
    projections run as ``kv_chunk``-row GEMM tiles and each tile's send is
    issued the moment its GEMM finishes (the next tile's GEMM hides the
    wire), under a ``contexts``-deep send window; the decode rank ticks
    arrivals off one chunk at a time (per-chunk receive semaphores).
  chained (``chained=1``, the non-fused CUCo point) — whole-tensor rounds,
    K's flight overlapping V's GEMM.
  sequential (``chained=0``) — each send awaited before the next GEMM
    starts (the host-driven shape inside one kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import (LEGACY_INTERPRET, interpret_params, shard_map,
                          compiler_params as tpu_compiler_params)
from repro.core.schedule import (RingSchedule, SendWindow,  # noqa: F401
                                 make_ring_schedule)


def _shuttle_kernel(x_ref, wk_ref, wv_ref, ko_ref, vo_ref,
                    kbuf, vbuf, ksend, krecv, vsend, vrecv,
                    *, axis, sched: RingSchedule, chained, counter,
                    contexts, decode_rank, pure=False):
    me = jax.lax.axis_index(axis)
    nc, cr = sched.nc, sched.kv_chunk
    dk = kbuf.shape[1]
    chunk_elems = cr * dk
    rows_total = sched.rows                  # V half's base row (pure mode)

    def chunk_dma(buf, o_ref, ssem, rsem_slot, c, nchunks):
        return pltpu.make_async_remote_copy(
            src_ref=buf.at[pl.ds(c * cr, nchunks * cr)],
            dst_ref=o_ref.at[pl.ds(c * cr, nchunks * cr)],
            send_sem=ssem, recv_sem=rsem_slot,
            device_id=decode_rank, device_id_type=pltpu.DeviceIdType.MESH)

    # contexts-deep send window over the schedule's (step, chunk) rounds
    # (the shared schedule.SendWindow): a round's K/V pair counts as ONE
    # window entry — the K half opens the round, the V half (issued after
    # the V tile's GEMM) amends it — so the executed window depth matches
    # the schedule contract and the l3 model's window_stall_factor credit.
    window = SendWindow(contexts)

    def gemm_tile(buf, w_ref, c, nchunks, base=0):
        rows = nchunks * cr
        if pure:
            # pure shuttle: the operand already holds finished K/V rows
            # (prefill-computed cache blocks) — stage the tile verbatim;
            # the K half reads rows [0, rows_total), V [rows_total, 2*...)
            buf.at[pl.ds(c * cr, rows)][...] = \
                x_ref[pl.ds(base + c * cr, rows)].astype(buf.dtype)
            return
        buf.at[pl.ds(c * cr, rows)][...] = jax.lax.dot_general(
            x_ref[pl.ds(c * cr, rows)], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(buf.dtype)

    def _prefill():
        if sched.fused:
            # TILE_FUSED: tile c's send issues the moment its GEMM ends —
            # K tile then V tile, so each wire hides behind the next GEMM
            for c in range(nc):
                gemm_tile(kbuf, wk_ref, c, 1)
                window.push([chunk_dma(kbuf, ko_ref, ksend, krecv.at[c],
                                       c, 1)])
                gemm_tile(vbuf, wv_ref, c, 1, rows_total)
                window.amend(chunk_dma(vbuf, vo_ref, vsend, vrecv.at[c],
                                       c, 1))
            window.drain()
        else:
            # one whole-tensor round: K opens it, V amends it after its
            # GEMM (chained — K flies while V computes); the sequential
            # shape drains K's send before the V GEMM starts
            gemm_tile(kbuf, wk_ref, 0, nc)
            window.push([chunk_dma(kbuf, ko_ref, ksend, krecv.at[0],
                                   0, nc)])
            if not chained:
                window.drain()       # sequential: drain before the V GEMM
            gemm_tile(vbuf, wv_ref, 0, nc, rows_total)
            if chained:
                window.amend(chunk_dma(vbuf, vo_ref, vsend, vrecv.at[0],
                                       0, nc))
            else:
                window.push([chunk_dma(vbuf, vo_ref, vsend, vrecv.at[0],
                                       0, nc)])
            window.drain()

    def _decode():
        if sched.fused and counter:
            # COUNTER: tick arrivals off one chunk at a time
            for c in range(nc):
                pltpu.semaphore_wait(krecv.at[c], chunk_elems)
                pltpu.semaphore_wait(vrecv.at[c], chunk_elems)
        elif sched.fused:
            for c in range(nc):      # SIGNAL: per-edge drain after the loop
                pltpu.semaphore_wait(krecv.at[c], chunk_elems)
            for c in range(nc):
                pltpu.semaphore_wait(vrecv.at[c], chunk_elems)
        else:
            pltpu.semaphore_wait(krecv.at[0], nc * chunk_elems)
            pltpu.semaphore_wait(vrecv.at[0], nc * chunk_elems)

    if LEGACY_INTERPRET:
        # The legacy interpreter discharges a remote DMA via an all_gather
        # every rank must reach — role-predicated issue would deadlock. Run
        # the full chain on BOTH ranks in lockstep: the decode rank's
        # outgoing copy carries its (zero) projections but the discharge
        # selects the prefill rank as source for the decode rank, and the
        # prefill rank's spurious self-delivery is masked by the caller
        # (outputs are only valid on the decode rank by contract).
        _prefill()
        _decode()
    else:
        pl.when(me != decode_rank)(_prefill)
        pl.when(me == decode_rank)(_decode)


def kv_shuttle_sharded(x, wk, wv, *, axis, chained=True, fused=False,
                       counter=False, kv_chunk=None, contexts=2,
                       sched: RingSchedule = None, decode_rank=1,
                       interpret=None, pure=False):
    """Per-device fn (under shard_map over a 2-rank axis).
    x: (T, d); wk/wv: (d, dk). Returns (K, V) — valid on the decode rank.
    An explicit ``sched`` takes precedence over the knob arguments.

    ``pure`` is the cache-handoff mode (no projection GEMMs): x holds the
    already-computed ``[K; V]`` rows stacked as (2N, w), wk/wv are unused
    dummies, and the same signal-chained K→V schedule ships the halves —
    returns (K, V) each (N, w), valid on the decode rank."""
    T, d = x.shape
    if pure:
        assert T % 2 == 0, "pure shuttle wants stacked [K; V] rows"
        rows, dk = T // 2, d
    else:
        rows, dk = T, wk.shape[1]
    if sched is None:
        sched = make_ring_schedule(2, rows, kv_chunk or (64 if fused else rows),
                                   fused)
    assert sched.rows == rows, (sched, rows)
    kern = functools.partial(_shuttle_kernel, axis=axis, sched=sched,
                             chained=chained, counter=counter,
                             contexts=contexts, decode_rank=decode_rank,
                             pure=pure)
    ip = interpret if interpret is not None else interpret_params()
    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec((T, d), lambda: (0, 0)),
            pl.BlockSpec(wk.shape, lambda: (0, 0)),
            pl.BlockSpec(wv.shape, lambda: (0, 0)),
        ],
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, dk), x.dtype)] * 2,
        scratch_shapes=[
            pltpu.VMEM((rows, dk), x.dtype),
            pltpu.VMEM((rows, dk), x.dtype),
            pltpu.SemaphoreType.DMA,                 # k send
            pltpu.SemaphoreType.DMA((sched.nc,)),    # k per-chunk recv
            pltpu.SemaphoreType.DMA,                 # v send
            pltpu.SemaphoreType.DMA((sched.nc,)),    # v per-chunk recv
        ],
        interpret=ip,
        compiler_params=tpu_compiler_params(collective_id=13),
    )(x, wk, wv)


def kv_shuttle(x, wk, wv, mesh, *, axis="x", chained=True, fused=False,
               counter=False, kv_chunk=None, contexts=2):
    """Global entry. x: (2, T, d) sharded over the 2-rank axis (prefill rank
    holds real activations); wk/wv replicated. Returns K/V gathered per rank
    — row [1] (decode rank) holds the shuttled projections."""
    from jax.sharding import PartitionSpec as P

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(None, None), P(None, None)),
                       out_specs=(P(axis), P(axis)), check_vma=False)
    def run(xs, k, v):
        ko, vo = kv_shuttle_sharded(xs[0], k, v, axis=axis, chained=chained,
                                    fused=fused, counter=counter,
                                    kv_chunk=kv_chunk, contexts=contexts)
        # the prefill rank never writes its own output buffers: zero them
        me = jax.lax.axis_index(axis)
        ko = jnp.where(me == 1, ko, 0.0)
        vo = jnp.where(me == 1, vo, 0.0)
        return ko[None], vo[None]

    return run(x, wk, wv)


def kv_cache_shuttle(kv, mesh, *, axis="x", chained=True, fused=False,
                     counter=False, kv_chunk=None, contexts=2):
    """Global cache-handoff entry (the disaggregated prefill→decode path
    ``serve/engine.py::prefill_remote`` rides). kv: (2, 2N, w) sharded over
    the 2-rank ``axis`` — the prefill rank's row holds the finished cache
    stacked ``[K; V]``, the decode rank's row is zeros. Returns (K, V) each
    (2, N, w); row [1] (the decode rank) holds the shuttled cache."""
    from jax.sharding import PartitionSpec as P

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis),),
                       out_specs=(P(axis), P(axis)), check_vma=False)
    def run(kvs):
        dummy = jnp.zeros((1, 1), kvs.dtype)
        ko, vo = kv_shuttle_sharded(kvs[0], dummy, dummy, axis=axis,
                                    chained=chained, fused=fused,
                                    counter=counter, kv_chunk=kv_chunk,
                                    contexts=contexts, pure=True)
        me = jax.lax.axis_index(axis)
        ko = jnp.where(me == 1, ko, 0.0)
        vo = jnp.where(me == 1, vo, 0.0)
        return ko[None], vo[None]

    return run(kv)
