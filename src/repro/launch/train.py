"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        [--smoke] [--steps N] [--ckpt DIR] [--moe-overlap] [--sp-residuals]

With --smoke (default when fewer devices than the production mesh are
available) the arch's reduced config trains on the local devices; on a real
slice the full config trains on the production mesh. Resumes automatically
from --ckpt; SIGTERM checkpoints and exits cleanly (preemption-safe).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, reduced
from repro.models import StepOptions
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-overlap", action="store_true")
    ap.add_argument("--moe-quantize", action="store_true")
    ap.add_argument("--sp-residuals", action="store_true")
    ap.add_argument("--loss-chunk", type=int, default=0)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    production = n_dev >= 256 and not args.smoke
    cfg = get_arch(args.arch) if production else reduced(get_arch(args.arch))
    mesh = None
    if production:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif n_dev >= 2:
        from repro.launch.mesh import make_mesh
        model = 2 if n_dev % 2 == 0 else 1
        mesh = make_mesh((n_dev // model, model), ("data", "model"))

    gb = args.global_batch or (256 if production else 8)
    sl = args.seq_len or (4096 if production else 128)
    opts = StepOptions(moe_overlap=args.moe_overlap,
                       moe_quantize=args.moe_quantize,
                       sp_residuals=args.sp_residuals,
                       loss_chunk=args.loss_chunk)
    tcfg = TrainConfig(steps=args.steps, global_batch=gb, seq_len=sl,
                       ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
                       opts=opts)
    print(f"[launch] arch={cfg.name} devices={n_dev} "
          f"mesh={dict(mesh.shape) if mesh else None} batch={gb} seq={sl}")
    losses, last, _ = train(cfg, tcfg, mesh=mesh)
    print(f"[launch] finished at step {last}: "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
