"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The single-pod mesh is 16x16 = 256 chips (v5e pod); the
multi-pod mesh adds a leading 'pod' axis over DCN (2 pods = 512 chips).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, found {len(devs)} — run via "
            f"repro.launch.dryrun (sets xla_force_host_platform_device_count)")
    return _make_mesh(shape, axes, devices=devs[:n])


def make_mesh(shape, axes):
    """Generic helper for tests/examples (small meshes)."""
    return _make_mesh(shape, axes)
