"""Render EXPERIMENTS.md tables from the dry-run artifacts."""
from __future__ import annotations

import json
import pathlib

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def load_cells():
    cells, skips = [], []
    for f in sorted(ARTIFACTS.glob("*.json")):
        d = json.loads(f.read_text())
        if "skipped" in d:
            skips.append(d)
        else:
            cells.append(d)
    return cells, skips


def fraction(d):
    """Roofline fraction: compute term / modeled step time (max of terms)."""
    r = d["roofline"]
    return r["compute_s"] / max(r["step_time_s"], 1e-12)


def roofline_table(mesh="16x16"):
    cells, skips = load_cells()
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline frac | useful FLOPs | peak GiB (scan/analytic) | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"])):
        if d["mesh"] != mesh:
            continue
        r = d["roofline"]
        m = d["memory"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {fraction(d) * 100:.1f}% | "
            f"{d['useful_flops_ratio']:.2f} | "
            f"{m['peak_bytes'] / 2**30:.1f} / "
            f"{m['analytic_peak_bytes'] / 2**30:.1f} | "
            f"{'Y' if m['fits_hbm_analytic'] else 'N'} |")
    for d in sorted(skips, key=lambda d: d["arch"]):
        lines.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — | — "
                     f"| — | skip: {d['skipped'][:40]}… |")
    return "\n".join(lines)


def dryrun_table():
    cells, _ = load_cells()
    lines = [
        "| arch | shape | mesh | FLOPs/dev | bytes/dev | ICI wire | DCN wire "
        "| #coll | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{r['flops']:.2e} | {r['bytes']:.2e} | "
            f"{r['ici_wire_bytes'] / 2**30:.2f} GiB | "
            f"{r['dcn_wire_bytes'] / 2**30:.2f} GiB | "
            f"{r['n_collectives']} | {d['compile_s']:.0f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        print(roofline_table(sys.argv[2] if len(sys.argv) > 2 else "16x16"))
    else:
        print(dryrun_table())
