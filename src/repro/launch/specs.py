"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs()`` returns weak-type-correct, shardable stand-ins — no device
allocation — for the step function of each cell kind:

  train_4k    -> train_step(params, opt_state, batch)
  prefill_32k -> prefill_step(params, batch)
  decode_32k / long_500k -> serve_step(params, cache, token, pos)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import Rules, sanitize_specs
from repro.models import (StepOptions, cache_specs, decode_step, init_params,
                          param_specs, prefill_step, train_loss)
from repro.optim import AdamWConfig, adamw_update, init_opt_state, opt_state_specs

SDS = jax.ShapeDtypeStruct


def rules_for(mesh, shape):
    kind = "decode" if shape.kind == "decode" else shape.kind
    return Rules(mesh, kind, long_context=(shape.seq_len > 100_000))


def batch_sds(cfg, shape, with_labels):
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": SDS((B, S), jnp.int32)}
    if with_labels:
        out["labels"] = SDS((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = SDS((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_patch_tokens:
        out["patches"] = SDS((B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    return out


def batch_shardings(cfg, shape, rules):
    b = rules.axes("batch")
    dp = rules.dp_size()
    if not (dp and shape.global_batch % dp == 0 and shape.global_batch >= dp):
        b = None
    out = {"tokens": P(b, None)}
    if shape.kind == "train":
        out["labels"] = P(b, None)
    if cfg.is_encoder_decoder:
        out["frames"] = P(b, None, None)
    if cfg.num_patch_tokens:
        out["patches"] = P(b, None, None)
    return out


def make_train_step(cfg, rules, opts: StepOptions, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, rules, opts))(params)
        new_params, new_state, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(cfg, rules, opts: StepOptions, seq_len):
    def prefill(params, batch):
        return prefill_step(params, batch, cfg, rules, seq_len=seq_len, opts=opts)
    return prefill


def make_serve_step(cfg, rules, opts: StepOptions):
    def serve_step(params, cache, token, pos):
        return decode_step(params, cache, token, pos, cfg, rules, opts=opts)
    return serve_step


def input_specs(cfg, shape, mesh, opts: StepOptions | None = None,
                opt_cfg: AdamWConfig | None = None):
    """Returns (step_fn, in_sds tuple, in_shardings tuple, donate_argnums)."""
    opts = opts or StepOptions()
    opt_cfg = opt_cfg or AdamWConfig()
    rules = rules_for(mesh, shape)
    key = jax.random.PRNGKey(0)
    p_sds = jax.eval_shape(lambda k: init_params(k, cfg), key)
    p_specs = sanitize_specs(param_specs(cfg, rules), p_sds, mesh) \
        if mesh is not None else jax.tree.map(lambda _: P(), p_sds)
    b_sds = batch_sds(cfg, shape, with_labels=(shape.kind == "train"))
    b_specs = batch_shardings(cfg, shape, rules)

    if shape.kind == "train":
        o_sds = jax.eval_shape(init_opt_state, p_sds)
        o_specs = opt_state_specs(p_specs, p_sds, rules) if mesh is not None \
            else jax.tree.map(lambda _: P(), o_sds)
        fn = make_train_step(cfg, rules, opts, opt_cfg)
        return fn, (p_sds, o_sds, b_sds), (p_specs, o_specs, b_specs), (0, 1)

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, rules, opts, shape.seq_len)
        return fn, (p_sds, b_sds), (p_specs, b_specs), ()

    # decode: one new token against a seq_len-deep cache
    c_sds, c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len, rules)
    if mesh is not None:
        c_specs = sanitize_specs(c_specs, c_sds, mesh)
    else:
        c_specs = jax.tree.map(lambda _: P(), c_sds)
    b = rules.axes("batch")
    dp = rules.dp_size()
    if not (dp and shape.global_batch % dp == 0 and shape.global_batch >= dp):
        b = None
    tok_sds = SDS((shape.global_batch, 1), jnp.int32)
    pos_sds = SDS((), jnp.int32)
    fn = make_serve_step(cfg, rules, opts)
    return fn, (p_sds, c_sds, tok_sds, pos_sds), \
        (p_specs, c_specs, P(b, None), P()), (1,)
