"""Production serving driver: batched generation with KV cache; optional
disaggregated prefill/decode handoff.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        [--batch 8] [--prompt-len 64] [--new-tokens 64] [--disaggregated]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import init_params
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--disaggregated", action="store_true")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch)) if args.smoke else get_arch(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 1,
        temperature=args.temperature))

    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len))
             .astype(np.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = np.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                                   np.float32)
    if cfg.num_patch_tokens:
        batch["patches"] = np.zeros(
            (args.batch, cfg.num_patch_tokens, cfg.d_model), np.float32)

    t0 = time.perf_counter()
    if args.disaggregated:
        handoff = eng.prefill_remote(batch)      # prefill tier
        toks = eng.decode_from_handoff(handoff, args.new_tokens)
    else:
        toks = eng.generate(batch, args.new_tokens)
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile); "
          f"mode={'disaggregated' if args.disaggregated else 'monolithic'}")
    print("[serve] sample:", np.asarray(toks[0][:16]))


if __name__ == "__main__":
    main()
