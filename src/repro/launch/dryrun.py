import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
derive the roofline terms from the compiled artifact.

The two lines above MUST stay the first statements in this module — jax locks
the device count on first init, and the production meshes need 512 placeholder
devices. Do not set this flag globally; smoke tests and benches see 1 device.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--jobs 4]
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, opts_kw=None,
             mesh=None, verbose=True):
    """Three-compile dry-run for one cell.

    Memory: the scan-over-layers module (the production schedule) — the loop
    body's buffers are allocated once, so the CPU backend's no-cross-layer-
    reuse accounting matches the real per-step working set.

    Cost/collectives: HLO cost analysis counts while-loop bodies once, so
    scan modules undercount per-step work; full unrolls of 30-50-layer models
    take 15+ minutes of GSPMD/CPU codegen. Instead we compile the SAME step
    unrolled at depth R=1 (one repeat unit) and R=2 and extrapolate linearly:
    per_layer = cost(R2) - cost(R1); total = cost(R1) + (R_full-1)*per_layer.
    The R1 module carries everything outside the layer stack (embeddings,
    loss, optimizer bookkeeping for the shared params) exactly once, so the
    extrapolation is exact for layer-homogeneous models (validated against a
    full llama3.2-1b unroll in EXPERIMENTS.md §Dry-run).
    """
    import dataclasses

    import jax
    from repro.configs import get_arch, get_shape
    from repro.core.cost_model import roofline_from_compiled
    from repro.core.hardware import extract_hardware_context
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs
    from repro.models import StepOptions

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name,
                "skipped": "full-attention arch: needs sub-quadratic attention"}
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    hw = extract_hardware_context(mesh)
    base_kw = dict(flash_threshold=2048, loss_chunk=512)
    base_kw.update(opts_kw or {})
    opts_unroll = StepOptions(scan_layers=False, **base_kw)
    opts_scan = StepOptions(scan_layers=True, **base_kw)
    t0 = time.time()

    def compile_with(c, opts):
        fn, in_sds, in_specs, donate = input_specs(c, shape, mesh, opts)
        with jax.set_mesh(mesh):
            jfn = jax.jit(fn, in_shardings=in_specs, donate_argnums=donate)
            return jfn.lower(*in_sds).compile()

    unit = cfg.repeat_unit
    R = cfg.num_repeats
    enc_per = (cfg.enc_layers // R) if cfg.is_encoder_decoder else 0

    def depth_cfg(k):
        kw = {"num_layers": k * unit}
        if cfg.is_encoder_decoder:
            kw["enc_layers"] = k * enc_per
        return dataclasses.replace(cfg, **kw)

    rep1 = roofline_from_compiled(compile_with(depth_cfg(1), opts_unroll),
                                  chips_per_pod=hw.chips_per_pod)
    if R > 1:
        rep2 = roofline_from_compiled(compile_with(depth_cfg(2), opts_unroll),
                                      chips_per_pod=hw.chips_per_pod)
        rep = rep1.extrapolate(rep2, R)
        mem = compile_with(cfg, opts_scan).memory_analysis()
    else:
        rep = rep1
        mem = compile_with(cfg, opts_scan).memory_analysis()
    t_compile = time.time() - t0
    t_lower = 0.0
    if verbose:
        print(mem)
        print({"flops": rep.flops, "bytes accessed": rep.bytes_accessed})

    # useful-FLOPs ratio: 6*N_active*D train, 2*N_active*D prefill/decode
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    per_dev_model_flops = model_flops / hw.n_chips
    arg_b = mem.argument_size_in_bytes
    tmp_b = mem.temp_size_in_bytes
    out_b = mem.output_size_in_bytes
    alias_b = mem.alias_size_in_bytes
    peak = arg_b + tmp_b + max(0, out_b - alias_b)
    # The CPU backend's buffer accounting does not model intra-body reuse, so
    # temp_bytes is an upper bound. Analytic activation estimate (documented
    # in EXPERIMENTS.md §Dry-run): remat residuals per layer + working set.
    dp = max(1, min(hw.n_chips // 16, shape.global_batch))
    B_l = max(1, shape.global_batch // dp)
    S = shape.seq_len if shape.kind != "decode" else 1
    d = cfg.d_model
    resid = cfg.num_layers * B_l * S * d * 2 if shape.kind == "train" else 0
    if base_kw.get("sp_residuals"):
        resid //= 16                     # remat carries sequence-sharded (TP)
    work = 8 * B_l * S * d * 4
    analytic = arg_b + resid + work
    # corrected memory term floored at one full read of the live arguments
    # (weights + cache must cross HBM at least once per step on any target)
    summ = rep.summary()
    summ["memory_corrected_s"] = max(
        summ["memory_corrected_s"], arg_b / hw.chip.hbm_bw)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in hw.mesh_shape),
        "n_chips": hw.n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {"argument_bytes": arg_b, "output_bytes": out_b,
                   "temp_bytes": tmp_b, "alias_bytes": alias_b,
                   "peak_bytes": peak,
                   "analytic_peak_bytes": int(analytic),
                   "fits_hbm": bool(peak <= hw.chip.hbm_bytes),
                   "fits_hbm_analytic": bool(analytic <= hw.chip.hbm_bytes)},
        "roofline": summ,
        "model_flops": model_flops,
        "useful_flops_ratio": (per_dev_model_flops / rep.flops
                               if rep.flops else 0.0),
        "collective_schedule": [c.describe() for c in sorted(
            rep.collectives, key=lambda c: -c.wire_bytes)[:20]],
    }
    if verbose:
        print(json.dumps({k: result[k] for k in
                          ("arch", "shape", "mesh", "roofline",
                           "useful_flops_ratio")}, indent=1, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--out", default=None)
    ap.add_argument("--moe-overlap", action="store_true")
    ap.add_argument("--moe-quantize", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-block", type=int, default=1024)
    ap.add_argument("--flash-threshold", type=int, default=8192)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--sp-residuals", action="store_true")
    ap.add_argument("--loss-chunk", type=int, default=512)
    args = ap.parse_args()

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    if args.all:
        from repro.configs import cells
        jobs = []
        for a, s, skip in cells():
            for mp in (False, True):
                tag = f"{a}__{s}__{'multi' if mp else 'single'}"
                out = ARTIFACTS / f"{tag}.json"
                if out.exists():
                    continue
                if skip:
                    out.write_text(json.dumps(
                        {"arch": a, "shape": s, "skipped": skip}))
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", str(out)]
                if mp:
                    cmd.append("--multi-pod")
                jobs.append((tag, cmd))
        running = []
        while jobs or running:
            while jobs and len(running) < args.jobs:
                tag, cmd = jobs.pop(0)
                print("START", tag, flush=True)
                running.append((tag, subprocess.Popen(
                    cmd, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)))
            for tag, proc in list(running):
                if proc.poll() is not None:
                    running.remove((tag, proc))
                    status = "OK" if proc.returncode == 0 else "FAIL"
                    print(f"DONE {tag}: {status}", flush=True)
                    if proc.returncode != 0:
                        err = proc.stderr.read().decode()[-2000:]
                        (ARTIFACTS / f"{tag}.err").write_text(err)
            time.sleep(2)
        return

    opts_kw = dict(moe_overlap=args.moe_overlap, moe_quantize=args.moe_quantize,
                   remat=not args.no_remat, kv_block=args.kv_block,
                   flash_threshold=args.flash_threshold,
                   seq_parallel=args.seq_parallel,
                   sp_residuals=args.sp_residuals, loss_chunk=args.loss_chunk)
    res = run_cell(args.arch, args.shape, args.multi_pod, opts_kw)
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(res, indent=1, default=str))


if __name__ == "__main__":
    main()
