"""JAX version compatibility shims.

The repo targets the current jax API (``jax.shard_map`` with ``check_vma``,
``pltpu.InterpretParams``, ``pltpu.CompilerParams``, ``jax.sharding.AxisType``,
``pltpu.sync_copy``); older jaxlibs (0.4.x) spell several of these differently
or lack them.  Every call site goes through this module so the rest of the
codebase is written against one API:

  * :func:`shard_map`       — ``jax.shard_map`` or the ``jax.experimental``
                              fallback, mapping ``check_vma`` -> ``check_rep``.
  * :func:`make_mesh`       — ``jax.make_mesh`` with ``axis_types`` only when
                              ``AxisType`` exists.
  * :func:`interpret_params`— ``pltpu.InterpretParams(...)`` when available,
                              else plain ``interpret=True`` (the generic pallas
                              interpreter, which on 0.4.x already discharges
                              remote DMAs under a single-axis shard_map).
  * :func:`compiler_params` — ``pltpu.CompilerParams`` or ``TPUCompilerParams``.
  * :func:`sync_copy`       — ``pltpu.sync_copy`` or an in-kernel element copy.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental.pallas import tpu as pltpu

# --------------------------------------------------------------- shard_map

if hasattr(jax, "shard_map"):                        # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                                # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` on new jax, experimental fallback on old jax.

    Usable both as ``shard_map(f, mesh=...)`` and via ``functools.partial``
    (decorator style), like the real thing.
    """
    kw = {_CHECK_KW: check_vma}
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


# --------------------------------------------------------------- mesh

def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` that tolerates jaxlibs without ``AxisType``."""
    shape, axes = tuple(shape), tuple(axes)
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    try:
        from jax.sharding import AxisType
        kw["axis_types"] = (AxisType.Auto,) * len(axes)
    except ImportError:
        pass
    return jax.make_mesh(shape, axes, **kw)


def axis_size(name):
    """``jax.lax.axis_size`` with a psum(1) fallback for old jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def set_mesh(mesh):
    """``jax.set_mesh`` context; old jax uses the Mesh object itself as a
    context manager (enough for NamedSharding-carrying code paths)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# --------------------------------------------------------------- pallas tpu

def interpret_params(**kwargs):
    """Interpret-mode marker for ``pl.pallas_call(interpret=...)``."""
    if hasattr(pltpu, "InterpretParams"):
        return pltpu.InterpretParams(**kwargs)
    return True


def compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (old)."""
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def sync_copy(src_ref, dst_ref):
    """``pltpu.sync_copy`` when present, else a direct in-kernel copy."""
    if hasattr(pltpu, "sync_copy"):
        return pltpu.sync_copy(src_ref, dst_ref)
    dst_ref[...] = src_ref[...]


# The legacy (0.4.x) pallas interpreter has no remote-signal discharge rule.
LEGACY_INTERPRET = not hasattr(pltpu, "InterpretParams")


def remote_semaphore_signal(sem, inc, *, device_id,
                            device_id_type=pltpu.DeviceIdType.MESH):
    """Signal a peer's semaphore; under the legacy interpreter fall back to a
    local signal — the simulation is sequential, so cross-device credit flow
    degenerates to per-device bookkeeping with identical counts."""
    if LEGACY_INTERPRET:
        pltpu.semaphore_signal(sem, inc)
    else:
        pltpu.semaphore_signal(sem, inc, device_id=device_id,
                               device_id_type=device_id_type)
