"""Static analyzer: host-driven program -> communication dependency graph
(paper §3.2 step 1, Appendix F).

The paper's analyzer walks user CUDA/NCCL code; ours walks the *jaxpr* of the
host-driven baseline. It finds every collective primitive (psum, all_to_all,
ppermute, all_gather, psum_scatter …), its buffer operands (shape/dtype/
bytes), producer and consumer equations, and the execution-order chain —
exactly the data the fast path needs to pick transformation targets.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

COLLECTIVE_PRIMS = {
    "psum": "all-reduce",
    "psum_scatter": "reduce-scatter",
    "all_gather": "all-gather",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
}


@dataclass
class CommNode:
    index: int                    # global eqn order
    prim: str                     # jax primitive name
    kind: str                     # HLO-style collective kind
    axes: tuple                   # mesh axes the collective runs over
    operands: list                # [(shape, dtype, bytes)]
    producers: list = field(default_factory=list)   # producing prim names
    consumers: list = field(default_factory=list)   # consuming prim names

    @property
    def payload_bytes(self):
        return sum(b for _, _, b in self.operands)

    def describe(self):
        shapes = ", ".join(f"{d}[{','.join(map(str, s))}]"
                           for s, d, _ in self.operands)
        return (f"#{self.index:<4d} {self.kind:20s} axes={self.axes} "
                f"({shapes})\n        produced by: {self.producers}"
                f"\n        consumed by: {self.consumers}")


@dataclass
class CommGraph:
    nodes: list
    n_eqns: int
    order: list                   # [(index, 'compute'|'communicate', prim)]

    @property
    def collective_bytes(self):
        return sum(n.payload_bytes for n in self.nodes)

    def phases(self):
        """Collapse consecutive compute eqns: [('compute', n), ('comm', node)]."""
        out = []
        run = 0
        for idx, kind, prim in self.order:
            if kind == "compute":
                run += 1
            else:
                if run:
                    out.append(("compute", run))
                    run = 0
                out.append(("communicate", prim))
        if run:
            out.append(("compute", run))
        return out

    def describe(self):
        lines = [f"Communication Graph ({len(self.nodes)} collectives, "
                 f"{self.n_eqns} eqns)"]
        for n in self.nodes:
            lines.append("  " + n.describe())
        lines.append("Execution Order (phases)")
        for kind, x in self.phases():
            lines.append(f"  {kind}: {x}")
        return "\n".join(lines)


def _nbytes(aval):
    n = int(np.prod(aval.shape)) if aval.shape else 1
    return n * aval.dtype.itemsize


def _sub_jaxprs(eqn):
    out = []
    for val in eqn.params.values():
        cands = val if isinstance(val, (tuple, list)) else (val,)
        for x in cands:
            if hasattr(x, "jaxpr"):          # ClosedJaxpr
                out.append(x.jaxpr)
            elif hasattr(x, "eqns"):         # Jaxpr
                out.append(x)
    return out


def _walk(jaxpr, nodes, order, producer, counter):
    """producer: var id -> (prim_name, CommNode | None)."""
    for eqn in jaxpr.eqns:
        idx = counter[0]
        counter[0] += 1
        prim = eqn.primitive.name
        srcs = []
        for v in eqn.invars:
            got = producer.get(id(v))
            if got is not None:
                src_prim, src_node = got
                srcs.append(src_prim)
                if src_node is not None and prim not in src_node.consumers:
                    src_node.consumers.append(prim)
        node = None
        if prim in COLLECTIVE_PRIMS:
            axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
            if not isinstance(axes, (tuple, list)):
                axes = (axes,)
            operands = [(tuple(v.aval.shape), str(v.aval.dtype), _nbytes(v.aval))
                        for v in eqn.invars if hasattr(v, "aval")
                        and hasattr(v.aval, "shape")]
            node = CommNode(index=idx, prim=prim, kind=COLLECTIVE_PRIMS[prim],
                            axes=tuple(axes), operands=operands,
                            producers=sorted(set(srcs)))
            nodes.append(node)
            order.append((idx, "communicate", prim))
        else:
            order.append((idx, "compute", prim))
        for v in eqn.outvars:
            producer[id(v)] = (prim, node)
        for sub in _sub_jaxprs(eqn):
            _walk(sub, nodes, order, producer, counter)


def analyze(fn, *example_args) -> CommGraph:
    """Build the communication dependency graph of ``fn``."""
    closed = jax.make_jaxpr(fn)(*example_args)
    nodes, order = [], []
    _walk(closed.jaxpr, nodes, order, {}, [0])
    return CommGraph(nodes=nodes, n_eqns=len(order), order=order)
