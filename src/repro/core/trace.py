"""Modeled-timeline tracing — the l3 cost model rendered as a Perfetto
trace (the observability layer of ROADMAP "auditable cost" work).

Two halves:

* :class:`TraceWriter` — a minimal Chrome-trace-event/Perfetto JSON
  emitter (complete spans, counter tracks, instant events, process/thread
  metadata). ``write()`` produces a file that loads directly in
  https://ui.perfetto.dev (timestamps in microseconds, the trace-event
  convention).
* :func:`schedule_timeline` — renders one directive's
  ``Workload.cost_breakdown`` as a per-rank modeled timeline laid over the
  ``CollectiveSchedule`` round order: the critical-path segments become
  spans, DMA-issue rounds (``issued_rounds()``) become instants inside the
  overlap span, the send-window occupancy (``send_window_depths()``)
  becomes a counter track, COUNTER arrival ticks land on the receive
  thread, window-recycle stalls render as explicit ``stall`` slices, and
  degraded-mode membership (``degrade(live_ranks)``) / fault plans splice
  recovery + remesh + straggler segments in.

**The invariant** (asserted in tests/test_trace.py): the sum of the
critical-path spans of any rendered timeline equals ``analytic_cost()``
(or ``fault_cost()`` when a plan is given) within 1e-6 — both are derived
from the same :class:`~repro.core.cost_model.CostBreakdown`, so the trace
audits exactly the scalar the cascade scores.

:class:`ScheduleProbe` is the interpret-mode observed-order probe: a
kernel body (``kernels/gemm_allgather.py``) records its actual DMA
issue/wait sequence at trace time, and :meth:`ScheduleProbe.check`
verifies it against the trace-time lockstep schedule — round order,
window cap, and arrival count must match the ``CollectiveSchedule``
contract the cost model charged.

Pure trace-time Python (no jax imports), mirroring core/schedule.py.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.core.cost_model import CostSegment
from repro.core.faults import REMESH_OVERHEAD

__all__ = [
    "TraceWriter", "Timeline", "ScheduleProbe", "schedule_timeline",
    "validate_trace",
]

# thread ids of the per-rank track layout (one process per modeled rank)
TID_CRITICAL = 0      # the critical-path spans (sum == analytic_cost)
TID_DMA = 1           # DMA-issue round instants
TID_ARRIVALS = 2      # receive-side readiness ticks


class TraceWriter:
    """Chrome-trace-event ("JSON Array with metadata") emitter.

    Event fields follow the trace-event spec: ``ph`` is the phase ("X"
    complete span, "C" counter, "i" instant, "M" metadata), ``ts``/``dur``
    are microseconds (floats allowed), ``pid``/``tid`` name the track.
    """

    def __init__(self):
        self.events = []

    # ------------------------------------------------------------- metadata
    def meta_process(self, pid, name):
        self.events.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": str(name)}})

    def meta_thread(self, pid, tid, name):
        self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": str(name)}})

    # --------------------------------------------------------------- events
    def span(self, name, ts_us, dur_us, *, pid=0, tid=0, cat="modeled",
             args=None):
        ev = {"ph": "X", "name": str(name), "cat": str(cat),
              "ts": float(ts_us), "dur": float(dur_us),
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name, ts_us, values, *, pid=0, cat="modeled"):
        """One sample of a counter track; ``values`` maps series name ->
        number (Perfetto stacks multi-series counters)."""
        self.events.append({"ph": "C", "name": str(name), "cat": str(cat),
                            "ts": float(ts_us), "pid": pid, "tid": 0,
                            "args": {k: float(v) for k, v in values.items()}})

    def instant(self, name, ts_us, *, pid=0, tid=0, cat="modeled",
                args=None):
        ev = {"ph": "i", "name": str(name), "cat": str(cat),
              "ts": float(ts_us), "pid": pid, "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # --------------------------------------------------------------- output
    def to_dict(self):
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def to_json(self, indent=None):
        return json.dumps(self.to_dict(), indent=indent)

    def write(self, path, indent=None):
        with open(path, "w") as f:
            f.write(self.to_json(indent=indent))


_REQUIRED = {"X": ("name", "ts", "dur", "pid", "tid"),
             "C": ("name", "ts", "pid", "args"),
             "i": ("name", "ts", "pid", "tid", "s"),
             "M": ("name", "pid", "args")}


def validate_trace(obj):
    """Structural validity of a trace dict (the schema tests/test_trace.py
    and the telemetry suite assert): a ``traceEvents`` list whose events
    carry the per-phase required fields, non-negative timestamps and
    durations. Returns the event count; raises ``ValueError`` on the first
    malformed event."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in _REQUIRED:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        for k in _REQUIRED[ph]:
            if k not in ev:
                raise ValueError(f"event {i} (ph={ph}): missing field {k!r}")
        if "ts" in ev and float(ev["ts"]) < 0:
            raise ValueError(f"event {i}: negative ts")
        if ph == "X" and float(ev["dur"]) < 0:
            raise ValueError(f"event {i}: negative dur")
    return len(events)


# --------------------------------------------------------------- timelines


@dataclass
class Timeline:
    """A rendered modeled timeline. ``critical_path_s`` is the sum of the
    critical-path spans (== ``analytic_cost`` / ``fault_cost`` by
    construction); ``breakdown`` is the CostBreakdown it was laid from."""
    writer: TraceWriter
    critical_path_s: float
    breakdown: object
    workload_name: str
    degraded: bool = False
    live_ranks: tuple = ()
    meta: dict = field(default_factory=dict)

    def to_dict(self):
        return self.writer.to_dict()

    def write(self, path, indent=None):
        self.writer.write(path, indent=indent)


_KIND_CAT = {"stall": "stall", "recovery": "recovery", "remesh": "recovery",
             "sync": "sync", "launch": "sync"}


def _anchor_segment(breakdown):
    """The span DMA rounds issue during: the first overlap segment, else
    the first wire segment, else the longest segment."""
    for kind in ("overlap", "wire"):
        for s in breakdown.segments:
            if s.kind == kind and s.dur_s > 0:
                return s
    return max(breakdown.segments, key=lambda s: s.dur_s)


def schedule_timeline(workload, directive, hw, *, live_ranks=None,
                      plan=None):
    """Render ``workload.cost_breakdown(directive, hw)`` as a per-rank
    Perfetto timeline (see module docstring for the track layout).

    ``live_ranks`` renders the degraded deployment (the workload reshapes
    via ``degrade`` exactly as ``fault_cost`` does). ``plan`` (a
    ``FaultPlan``) additionally splices the recovery / remesh / straggler
    segments so the critical path equals ``fault_cost(workload, directive,
    hw, plan)``. The healthy call renders ``analytic_cost``.
    """
    base = workload
    extra = []          # (name, dur_s, kind) appended after the breakdown
    if plan is not None:
        if live_ranks is not None:
            raise ValueError("pass live_ranks or plan, not both")
        live_ranks = plan.live_ranks(base.n_dev)
    degraded = False
    live = tuple(range(base.n_dev))
    if live_ranks is not None:
        from repro.core.schedule import check_live
        live = check_live(live_ranks, base.n_dev)
        if len(live) < base.n_dev:
            degraded = True
            dead = base.n_dev - len(live)
            if plan is not None:
                # the fault_cost recovery terms, in fault_cost's order
                extra.append(("state_recovery",
                              dead * base.state_bytes_per_rank()
                              / hw.chip.ici_link_bw, "recovery"))
                extra.append(("remesh", REMESH_OVERHEAD, "remesh"))
            workload = base.degrade(live)
    if plan is not None:
        stall = plan.straggler_stall_s(directive.contexts)
        if stall or not extra:
            extra.append(("straggler_stall", stall, "stall"))

    bd = workload.cost_breakdown(directive, hw)
    w = TraceWriter()
    n = workload.n_dev
    sched = bd.schedule
    contexts = int(bd.knobs.get("contexts", max(1, directive.contexts)))

    critical = 0.0
    for rank in range(n):
        w.meta_process(rank, f"rank {rank} · {workload.name}")
        w.meta_thread(rank, TID_CRITICAL, "modeled critical path")
        if degraded:
            w.instant("degraded: live=" + ",".join(map(str, live)), 0.0,
                      pid=rank, tid=TID_CRITICAL, cat="fault",
                      args={"live_ranks": list(live)})
        cursor = 0.0
        rank_total = 0.0
        for seg in tuple(bd.segments) + tuple(
                CostSegment(nm, dur, kind) for nm, dur, kind in extra):
            dur_us = seg.dur_s * 1e6
            if dur_us > 0.0:
                args = {"kind": seg.kind}
                args.update({k: v for k, v in seg.meta.items()
                             if isinstance(v, (int, float, str, bool))})
                w.span(seg.name, cursor, dur_us, pid=rank, tid=TID_CRITICAL,
                       cat=_KIND_CAT.get(seg.kind, "modeled"), args=args)
            cursor += dur_us
            rank_total += seg.dur_s
        if rank == 0:
            critical = rank_total

        if sched is None:
            continue
        # ------------- schedule detail tracks (kernelized directives only)
        rounds = list(sched.rounds)
        depths = sched.send_window_depths(contexts)
        anchor = _anchor_segment(bd)
        a0 = 0.0
        for seg in bd.segments:
            if seg is anchor:
                break
            a0 += seg.dur_s * 1e6
        a_dur = anchor.dur_s * 1e6
        w.meta_thread(rank, TID_DMA, "dma issue rounds")
        w.meta_thread(rank, TID_ARRIVALS, "arrival ticks")
        step = a_dur / max(1, len(rounds))
        for i, (edge, tile) in enumerate(rounds):
            ts = a0 + i * step
            w.instant(f"dma issue ({edge},{tile})", ts, pid=rank,
                      tid=TID_DMA, cat="dma",
                      args={"round": i, "edge": edge, "tile": tile})
            w.counter("send window", ts, {"in_flight": depths[i]}, pid=rank)
        if rounds:
            w.counter("send window", a0 + a_dur, {"in_flight": 0}, pid=rank)
        ticks = _arrival_ticks(bd, sched)
        tstep = a_dur / max(1, ticks)
        for i in range(ticks):
            w.instant(f"arrival tick {i}", a0 + (i + 1) * tstep, pid=rank,
                      tid=TID_ARRIVALS, cat="dma", args={"tick": i})

    return Timeline(writer=w, critical_path_s=critical, breakdown=bd,
                    workload_name=workload.name, degraded=degraded,
                    live_ranks=live,
                    meta={"directive": directive.as_dict(),
                          "plan": getattr(plan, "name", None)})


def _arrival_ticks(bd, sched):
    """Receive-side readiness ticks of the rendered schedule: prefer the
    count the cost model actually charged (the ``tile_sync`` segment's
    meta), fall back to the schedule's own accounting."""
    for s in bd.segments:
        if "ticks" in s.meta:
            return int(s.meta["ticks"])
    if hasattr(sched, "completion_ticks"):
        return int(sched.completion_ticks(bool(bd.knobs.get("counter", True))))
    return 0


# ------------------------------------------------- observed-order probe


class ScheduleProbe:
    """Records the DMA issue/wait order a kernel body actually performs at
    trace time (interpret mode unrolls the body in Python, so a plain
    Python recorder sees the real sequence), then checks it against the
    trace-time lockstep schedule the cost model charged.

    Kernels accept ``probe=None`` and call :meth:`issue` /
    :meth:`wait_send` / :meth:`wait_recv` next to the corresponding DMA
    operations; :meth:`check` asserts the ``CollectiveSchedule`` contract:

    * the issued ``(edge, tile)`` order equals ``schedule.rounds``,
    * the replayed in-flight send depth never exceeds ``contexts`` and
      matches ``send_window_depths`` after every issue,
    * every in-flight send is retired (drained) by kernel end,
    * the receive-wait count equals ``completion_ticks``.
    """

    def __init__(self):
        self.events = []

    def reset(self):
        self.events = []

    def issue(self, edge, tile):
        self.events.append(("issue", int(edge), int(tile)))

    def wait_send(self):
        self.events.append(("wait_send",))

    def wait_recv(self, slot=None):
        self.events.append(("wait_recv",
                            None if slot is None else int(slot)))

    def mark(self, name):
        """Freeform ordering marker (e.g. the two-stream serving kernel
        stamps ``shared_ffn`` between the last dispatch issue and the
        window drain). Ignored by :meth:`check`; asserted via
        :attr:`marks` by callers that care about compute/DMA interleave."""
        self.events.append(("mark", str(name)))

    @property
    def marks(self):
        return [e[1] for e in self.events if e[0] == "mark"]

    @property
    def issued(self):
        return [(e[1], e[2]) for e in self.events if e[0] == "issue"]

    @property
    def recv_waits(self):
        return [e for e in self.events if e[0] == "wait_recv"]

    def check(self, schedule, contexts, counter=True):
        """Assert the observed order satisfies the schedule contract;
        returns a summary dict on success, raises ``AssertionError`` with
        the first divergence otherwise."""
        cap = max(1, int(contexts))
        rounds = list(schedule.rounds)
        assert self.issued == rounds, (
            f"observed issue order diverges from schedule.rounds:\n"
            f"  observed {self.issued[:8]}...\n  expected {rounds[:8]}...")
        depth, depths = 0, []
        for ev in self.events:
            if ev[0] == "issue":
                depth += 1
                assert depth <= cap, (
                    f"send window exceeded: depth {depth} > contexts {cap}")
                depths.append(depth)
            elif ev[0] == "wait_send":
                depth -= 1
                assert depth >= 0, "wait_send with no in-flight send"
        assert depth == 0, f"{depth} sends left in flight (window not drained)"
        expect = schedule.send_window_depths(cap)
        assert depths == list(expect), (
            f"window depth profile diverges from send_window_depths:\n"
            f"  observed {depths[:12]}...\n  expected {list(expect)[:12]}...")
        ticks = schedule.completion_ticks(counter) \
            if hasattr(schedule, "completion_ticks") else None
        n_recv = len(self.recv_waits)
        if ticks is not None:
            assert n_recv == ticks, (
                f"receive waits {n_recv} != completion_ticks {ticks}")
        return {"rounds": len(rounds), "max_depth": max(depths, default=0),
                "recv_waits": n_recv}
