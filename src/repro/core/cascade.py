"""Cascade evaluation (paper §3.3): every offspring passes a fast-fail
three-level cascade — l1 build+compile, l2 numerical verification against the
workload oracle, l3 benchmark. Score = 10000 / (1 + t_ms); candidates failing
l1/l2 score 0 and carry a diagnostic for the feedback loop.

l3 on this CPU-only container is the analytic v5e roofline composition of the
workload at its full deployment shape (DESIGN.md §2); ``wallclock=True``
additionally times the small-shape execution (used by ablation benchmarks).
"""
from __future__ import annotations

import time
import traceback
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design_space import Directive


@dataclass
class EvalResult:
    level: int                    # highest level passed (0..3)
    score: float
    t_model_ms: float = float("inf")
    t_wall_ms: float = float("inf")
    diagnostic: str = ""
    hlo_ops: dict = field(default_factory=dict)

    @property
    def ok(self):
        return self.level >= 3


@dataclass
class Candidate:
    directive: Directive
    gen: int = 0
    island: int = 0
    parent_id: int = -1
    mutation: str = "seed"
    cid: int = -1
    result: EvalResult | None = None
    code_text: str = ""           # jaxpr text of the built program

    @property
    def score(self):
        return self.result.score if self.result else 0.0


class CascadeEvaluator:
    def __init__(self, workload, mesh, hw, *, rtol=2e-3, wallclock=False,
                 verify_inputs=None):
        self.workload = workload
        self.mesh = mesh
        self.hw = hw
        self.rtol = rtol
        self.wallclock = wallclock
        key = jax.random.PRNGKey(1234)
        self.inputs = verify_inputs or workload.example_inputs(key, mesh)
        self.expected = workload.reference(*self.inputs)

    def evaluate(self, cand: Candidate) -> EvalResult:
        d = cand.directive
        # ---- l1: directive validity + build + trace/compile -------------
        viol = self.workload.check(d, self.hw)
        if viol:
            return EvalResult(0, 0.0, diagnostic="invalid directive: "
                              + "; ".join(viol))
        try:
            fn = self.workload.build(d, self.mesh)
            jfn = jax.jit(fn)
            lowered = jfn.lower(*self.inputs)
            cand.code_text = lowered.as_text()[:200_000]
        except Exception:
            return EvalResult(0, 0.0, diagnostic="l1 build/lower failed:\n"
                              + traceback.format_exc()[-1500:])
        # ---- l2: numerical verification ---------------------------------
        try:
            out = jfn(*self.inputs)
            tol = self.rtol
            if d.tunable("wire_i8", 0):
                tol = max(tol, 8e-2)          # quantized wire is lossy by design
            for got, exp in zip(jax.tree.leaves(out),
                                jax.tree.leaves(self.expected)):
                got = np.asarray(got, np.float32)
                exp = np.asarray(exp, np.float32)
                if not np.all(np.isfinite(got)):
                    return EvalResult(1, 0.0, diagnostic=(
                        "l2 verify failed: non-finite values (deadlock-free "
                        "but corrupt transfer — check completion/ordering)"))
                err = np.max(np.abs(got - exp)) / (np.max(np.abs(exp)) + 1e-9)
                if err > tol:
                    return EvalResult(1, 0.0, diagnostic=(
                        f"l2 verify failed: rel err {err:.3e} > {tol:.0e} "
                        f"(placement={d.placement}, completion={d.completion})"))
        except Exception:
            return EvalResult(1, 0.0, diagnostic="l2 execution failed:\n"
                              + traceback.format_exc()[-1500:])
        # ---- l3: benchmark ----------------------------------------------
        t_model = self.workload.analytic_cost(d, self.hw)
        t_ms = t_model * 1e3
        t_wall = float("inf")
        if self.wallclock:
            jfn(*self.inputs)
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(jfn(*self.inputs))
            t_wall = (time.perf_counter() - t0) / 3 * 1e3
        return EvalResult(3, 10000.0 / (1.0 + t_ms), t_model_ms=t_ms,
                          t_wall_ms=t_wall,
                          diagnostic=f"ok: modeled {t_ms:.3f} ms")
