"""Cascade evaluation (paper §3.3): every offspring passes a fast-fail
cascade — l0 static schedule verification (``core/verify.py``: the
symbolic lockstep executor proves deadlock freedom, slot-reuse safety,
window-cap/drain invariants and wire conservation before any tracing),
l1 build+compile, l2 numerical verification against the workload oracle,
l3 benchmark. Score = 10000 / (1 + t_ms); candidates failing l0/l1/l2
score 0 and carry a diagnostic for the feedback loop plus a deterministic
``rejection`` class ("l0:<checker code>", "l1:build", "l2:mismatch", ...)
for telemetry.

l3 on this CPU-only container is the analytic v5e roofline composition of the
workload at its full deployment shape (DESIGN.md §2); ``wallclock=True``
additionally times the small-shape execution (used by ablation benchmarks).

Hardened for unattended search (the slow path runs thousands of candidates
with nobody watching):

* ``timeout_s`` — a per-candidate wall-clock budget. Evaluation runs on a
  daemon worker thread; a candidate that wedges (infinite trace, hung
  interpret) is abandoned at the deadline, recorded in ``quarantine``, and
  scored 0 with ``quarantined=True`` — it can never stall ``slow_path.py``.
* one retry with backoff for flaky l2 *executions* (``l2_retries``): a
  transient runtime error re-runs after ``backoff_s``; a deterministic
  verify mismatch never retries. ``EvalResult.retries`` records the count.
* ``fault_plans`` — fault scenarios (``core/faults.py``) priced at l3 into
  ``EvalResult.fault_report``; ``fault_weight`` folds the mean degraded-ms
  penalty into the score so the search optimizes a (throughput,
  fault-survival) trade-off.

Batched evaluation (docs/search.md — the throughput half of ROADMAP open
item 3): :meth:`CascadeEvaluator.evaluate_batch` evaluates a whole
generation at once. l1 validity/build and l3 analytic costing are pure
trace-time math per candidate; the expensive part — the l2 interpret
execution — fans out across a bounded ``concurrent.futures`` worker pool
(``batch_workers``). Each pool task runs the *same* guarded per-candidate
cascade the sequential path runs (same ``_run_l2`` seam, same
``timeout_s``/quarantine discipline: the abandonable deadline thread stays
per candidate, so a wedged candidate releases its pool slot at the
deadline), with record/quarantine *publication* deferred and replayed in
input order — so scores, levels, retries, ``EvalResult``s and the
``records``/``quarantine`` streams are identical to calling
:meth:`evaluate` per candidate (wall-clock timings in ``levels_s`` aside).
"""
from __future__ import annotations

import os
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.design_space import Directive


@dataclass
class EvalResult:
    level: int                    # highest level passed (0..3)
    score: float
    t_model_ms: float = float("inf")
    t_wall_ms: float = float("inf")
    diagnostic: str = ""
    hlo_ops: dict = field(default_factory=dict)
    fault_report: dict = field(default_factory=dict)  # plan -> healthy/degraded ms
    quarantined: bool = False     # abandoned at the wall-clock deadline
    retries: int = 0              # flaky-l2 re-executions that were needed
    rejection: str = ""           # deterministic rejection class ("" = passed)
    record: object = None         # telemetry.EvalRecord (every path sets one)

    @property
    def ok(self):
        return self.level >= 3


@dataclass
class Candidate:
    directive: Directive
    gen: int = 0
    island: int = 0
    parent_id: int = -1
    mutation: str = "seed"
    cid: int = -1
    result: EvalResult | None = None
    code_text: str = ""           # jaxpr text of the built program
    cached: bool = False          # result reused from a warm-start store

    @property
    def score(self):
        return self.result.score if self.result else 0.0


class CascadeEvaluator:
    def __init__(self, workload, mesh, hw, *, rtol=2e-3, wallclock=False,
                 verify_inputs=None, timeout_s=None, l2_retries=1,
                 backoff_s=0.05, fault_plans=(), fault_weight=0.0,
                 batch_workers=None):
        self.workload = workload
        self.mesh = mesh
        self.hw = hw
        self.rtol = rtol
        self.wallclock = wallclock
        self.timeout_s = timeout_s
        self.l2_retries = max(0, int(l2_retries))
        self.backoff_s = backoff_s
        self.fault_plans = tuple(fault_plans)
        self.fault_weight = fault_weight
        self.batch_workers = max(1, int(
            batch_workers or min(4, os.cpu_count() or 1)))
        self.quarantine = []          # wedged-candidate diagnostics
        self.records = []             # telemetry.EvalRecord per evaluation
        key = jax.random.PRNGKey(1234)
        self.inputs = verify_inputs or workload.example_inputs(key, mesh)
        self.expected = workload.reference(*self.inputs)

    def evaluate(self, cand: Candidate) -> EvalResult:
        """Evaluate one candidate under the wall-clock budget, publishing
        its record (and quarantine entry, if any) immediately."""
        res, _ = self._guarded(cand, publish=True)
        return res

    def evaluate_batch(self, cands, *, max_workers=None) -> list:
        """Evaluate a whole generation at once — the parity contract
        (docs/search.md): the returned ``EvalResult``s, the appended
        ``records`` and the ``quarantine`` entries are identical to calling
        :meth:`evaluate` per candidate in order (wall timings aside).

        The l2 interpret executions fan out across a bounded worker pool of
        at most ``max_workers`` (default ``batch_workers``) threads; l1
        build/lower and l3 analytic costing ride the same per-candidate
        pass (pure trace-time math — cheap and thread-safe). Each pool task
        keeps the sequential path's per-candidate ``timeout_s`` discipline:
        the abandonable deadline thread is spawned inside the pool task, so
        a wedged candidate frees its pool slot at the deadline instead of
        starving the batch. Publication of records and quarantine entries
        is deferred and replayed in input order after the pool drains."""
        cands = list(cands)
        if not cands:
            return []
        workers = max(1, min(int(max_workers or self.batch_workers),
                             len(cands)))
        outs = [None] * len(cands)

        def one(i):
            outs[i] = self._guarded(cands[i], publish=False)

        if workers == 1:
            for i in range(len(cands)):
                one(i)
        else:
            with ThreadPoolExecutor(max_workers=workers,
                                    thread_name_prefix="cascade-batch") as px:
                list(px.map(one, range(len(cands))))
        results = []
        for res, qentry in outs:
            if res.record is not None:
                self.records.append(res.record)
            if qentry is not None:
                self.quarantine.append(qentry)
            results.append(res)
        return results

    def _guarded(self, cand: Candidate, publish=True):
        """The full timeout-guarded cascade for one candidate: the body
        runs on a daemon thread; past ``timeout_s`` the candidate is
        quarantined (the wedged thread is abandoned — it holds no locks
        the search needs) and the caller moves on. Returns ``(result,
        quarantine_entry_or_None)``; with ``publish=False`` nothing is
        appended to ``records``/``quarantine`` — the batch path replays
        publication in input order."""
        if not self.timeout_s:
            return self._evaluate(cand, publish=publish), None
        box = {}

        def run():
            try:
                box["res"] = self._evaluate(cand, publish=publish)
            except BaseException as e:        # surfaced below, never lost
                box["err"] = e

        th = threading.Thread(target=run, daemon=True,
                              name=f"cascade-eval-{cand.cid}")
        t0 = time.perf_counter()
        th.start()
        th.join(self.timeout_s)
        if th.is_alive():
            elapsed = time.perf_counter() - t0
            stage = getattr(cand, "_stage", "")
            diag = (f"quarantined: evaluation exceeded {self.timeout_s:.2f}s "
                    "wall-clock (wedged build/execute abandoned"
                    + (f" at {stage}" if stage else "") + ")")
            # flag first: the abandoned thread must not append a late
            # duplicate record if it ever comes back from the wedge
            cand._quarantined = True
            res = EvalResult(0, 0.0, diagnostic=diag, quarantined=True,
                             rejection="quarantine")
            res = self._record(cand, res, {"quarantine": elapsed},
                               force=True, publish=publish)
            entry = {
                "cid": cand.cid, "directive": repr(cand.directive),
                "elapsed_s": elapsed, "diagnostic": diag, "stage": stage,
                "record": res.record.to_dict()}
            if publish:
                self.quarantine.append(entry)
            return res, entry
        if "err" in box:
            elapsed = time.perf_counter() - t0
            e = box["err"]
            res = EvalResult(0, 0.0, rejection="error",
                             diagnostic="evaluator error:\n" + "".join(
                traceback.format_exception(type(e), e, e.__traceback__))[-1500:])
            return self._record(cand, res, {"error": elapsed},
                                publish=publish), None
        return box["res"], None

    def quarantine_report(self):
        """Diagnostics of every candidate abandoned at the deadline."""
        return list(self.quarantine)

    def _run_l2(self, jfn):
        """The l2 execution boundary — a deliberate seam: tests and fault
        suites wrap it to inject flaky executions or wire faults."""
        return jfn(*self.inputs)

    def _verify_l0(self, d):
        """The l0 static-verification boundary — a seam like
        :meth:`_run_l2`: tests wrap it to inject mutated programs.
        Returns a ``verify.VerifyReport`` or ``None`` when the directive
        realizes no collective schedule (XLA backends, solo tiers) — a
        vacuous pass."""
        from repro.core.verify import verify_directive
        return verify_directive(self.workload, d)

    def _record(self, cand, res: EvalResult, levels, *, fault_penalty_ms=0.0,
                force=False, publish=True) -> EvalResult:
        """Attach the structured telemetry row for one evaluation; every
        evaluate path (success, l1/l2 fail, error, quarantine) routes
        through here. A candidate already quarantined by the deadline
        watcher is skipped unless ``force``d — the abandoned worker thread
        must not append a late duplicate. ``publish=False`` attaches the
        record to the result only; the batch path appends it to
        ``records`` later, in input order."""
        if getattr(cand, "_quarantined", False) and not force:
            return res
        from repro.core.telemetry import EvalRecord
        try:
            knobs = dict(self.workload.kernel_knobs(cand.directive))
        except Exception:
            knobs = {}
        rec = EvalRecord(
            cid=cand.cid, gen=cand.gen, island=cand.island,
            mutation=cand.mutation, directive=repr(cand.directive),
            level=res.level, score=res.score,
            t_model_ms=res.t_model_ms
            if np.isfinite(res.t_model_ms) else None,
            t_wall_ms=res.t_wall_ms if np.isfinite(res.t_wall_ms) else None,
            levels_s={k: float(v) for k, v in levels.items()},
            retries=res.retries, quarantined=res.quarantined,
            fault_penalty_ms=float(fault_penalty_ms), knobs=knobs,
            diagnostic=res.diagnostic,
            elapsed_s=float(sum(levels.values())),
            rejection=res.rejection,
            stage=getattr(cand, "_stage", ""))
        res.record = rec
        if publish:
            self.records.append(rec)
        return res

    def _evaluate(self, cand: Candidate, publish=True) -> EvalResult:
        d = cand.directive
        levels = {}
        # ---- l0: directive validity + static schedule verification ------
        cand._stage = "l0"
        viol = self.workload.check(d, self.hw)
        if viol:
            return self._record(
                cand, EvalResult(0, 0.0, rejection="invalid",
                                 diagnostic="invalid directive: "
                                 + "; ".join(viol)), levels, publish=publish)
        t0 = time.perf_counter()
        vrep = self._verify_l0(d)
        levels["l0"] = time.perf_counter() - t0
        if vrep is not None and not vrep.ok:
            # a structured VerifyError diagnostic: the mutation feedback
            # loop reads the class prefix, telemetry keys on `rejection`
            return self._record(
                cand, EvalResult(0, 0.0,
                                 rejection="l0:" + vrep.errors[0].code,
                                 diagnostic="l0 schedule verify failed: "
                                 + vrep.summary()), levels, publish=publish)
        # ---- l1: build + trace/compile ----------------------------------
        cand._stage = "l1"
        t1 = time.perf_counter()
        try:
            fn = self.workload.build(d, self.mesh)
            jfn = jax.jit(fn)
            lowered = jfn.lower(*self.inputs)
            cand.code_text = lowered.as_text()[:200_000]
        except Exception:
            levels["l1"] = time.perf_counter() - t1
            return self._record(
                cand, EvalResult(0, 0.0, rejection="l1:build",
                                 diagnostic="l1 build/lower failed:\n"
                                 + traceback.format_exc()[-1500:]), levels,
                publish=publish)
        levels["l1"] = time.perf_counter() - t1
        # ---- l2: numerical verification ---------------------------------
        # transient execution errors retry with backoff; a deterministic
        # verify mismatch below never does
        cand._stage = "l2"
        t2 = time.perf_counter()
        retries = 0
        while True:
            try:
                out = self._run_l2(jfn)
                break
            except Exception:
                if retries >= self.l2_retries:
                    levels["l2"] = time.perf_counter() - t2
                    return self._record(
                        cand, EvalResult(1, 0.0, retries=retries,
                                         rejection="l2:execute",
                                         diagnostic="l2 execution failed:\n"
                                         + traceback.format_exc()[-1500:]),
                        levels, publish=publish)
                retries += 1
                time.sleep(self.backoff_s * retries)
        tol = self.rtol
        if d.tunable("wire_i8", 0):
            tol = max(tol, 8e-2)          # quantized wire is lossy by design
        for got, exp in zip(jax.tree.leaves(out),
                            jax.tree.leaves(self.expected)):
            got = np.asarray(got, np.float32)
            exp = np.asarray(exp, np.float32)
            if not np.all(np.isfinite(got)):
                levels["l2"] = time.perf_counter() - t2
                return self._record(
                    cand, EvalResult(1, 0.0, retries=retries,
                                     rejection="l2:nonfinite", diagnostic=(
                        "l2 verify failed: non-finite values (deadlock-free "
                        "but corrupt transfer — check completion/ordering)")),
                    levels, publish=publish)
            err = np.max(np.abs(got - exp)) / (np.max(np.abs(exp)) + 1e-9)
            if err > tol:
                levels["l2"] = time.perf_counter() - t2
                return self._record(
                    cand, EvalResult(1, 0.0, retries=retries,
                                     rejection="l2:mismatch", diagnostic=(
                        f"l2 verify failed: rel err {err:.3e} > {tol:.0e} "
                        f"(placement={d.placement}, "
                        f"completion={d.completion})")), levels,
                    publish=publish)
        levels["l2"] = time.perf_counter() - t2
        # ---- l3: benchmark ----------------------------------------------
        cand._stage = "l3"
        t3 = time.perf_counter()
        t_model = self.workload.analytic_cost(d, self.hw)
        t_ms = t_model * 1e3
        fault_report = {}
        if self.fault_plans:
            from repro.core.faults import survival_report
            fault_report = survival_report(self.workload, d, self.hw,
                                           self.fault_plans)
        # fault-survival trade-off: the score price of a plan is its mean
        # degraded-over-healthy penalty; a plan the candidate cannot
        # survive prices as +inf and zeroes the score (level stays 3 — the
        # candidate is correct, just fragile)
        t_eff = t_ms
        if fault_report and self.fault_weight:
            pens = [max(0.0, e["degraded_ms"] - e["healthy_ms"])
                    for e in fault_report.values()]
            t_eff = t_ms + self.fault_weight * sum(pens) / len(pens)
        levels["l3"] = time.perf_counter() - t3
        t_wall = float("inf")
        if self.wallclock:
            from repro.core.telemetry import wallclock_us
            tw = time.perf_counter()
            t_wall = wallclock_us(jfn, self.inputs) / 1e3
            levels["wallclock"] = time.perf_counter() - tw
        return self._record(
            cand, EvalResult(3, 10000.0 / (1.0 + t_eff), t_model_ms=t_ms,
                             t_wall_ms=t_wall, fault_report=fault_report,
                             retries=retries,
                             diagnostic=f"ok: modeled {t_ms:.3f} ms"),
            levels, fault_penalty_ms=t_eff - t_ms, publish=publish)
