"""CUCo core: the paper's contribution as a composable JAX module.

Pipeline:  comm_graph.analyze (static analyzer)
        -> fast_path (correctness-first transformation to a verified seed)
        -> slow_path (island evolution over the design space C)
with cascade evaluation, MAP-Elites archive, candidate DB + novelty filter,
meta-summarizer, and the v5e roofline cost model.
"""
from repro.core.design_space import (Directive, CONSERVATIVE, DIMENSIONS,
                                     EXPERT_SYSTEMS, TUNABLES, violations,
                                     is_valid, random_directive,
                                     enumerate_valid, directive_key,
                                     directive_from_dict)
from repro.core.hardware import V5E, ChipSpec, HardwareContext, \
    extract_hardware_context
from repro.core.cost_model import (CostBreakdown, CostSegment,
                                   RooflineReport, parse_collectives,
                                   per_tile_exposed_s, roofline_from_compiled,
                                   window_stall_factor)
from repro.core.trace import (ScheduleProbe, Timeline, TraceWriter,
                              schedule_timeline, validate_trace)
from repro.core.telemetry import (EvalRecord, MetricsRegistry,
                                  SearchTelemetry, wallclock_us)
from repro.core.schedule import (CollectiveSchedule, BroadcastSchedule,
                                 DispatchSchedule, RingSchedule, SendWindow,
                                 check_live, make_broadcast_schedule,
                                 make_ring_schedule, make_schedule,
                                 respill_counts, sanitize_tile,
                                 send_window_depths)
from repro.core.verify import (CHECKS, MUTATION_CLASSES, Op, Program,
                               VerifyError, VerifyReport, apply_mutation,
                               degrade_errors, directive_programs,
                               lower_schedule, mutation_corpus,
                               verify_directive, verify_program,
                               verify_schedule)
from repro.core.faults import (FaultPlan, FaultSpec, fault_cost,
                               inject_wire_fault, survival_report)
from repro.core.comm_graph import analyze as analyze_comm_graph
from repro.core.cascade import Candidate, CascadeEvaluator, EvalResult
from repro.core.database import CandidateDB, StoreError, embed_code
from repro.core.archive import MapElitesArchive
from repro.core.mutation import (HeuristicMutator, LLMMutator,
                                 MutationContext, parse_directive)
from repro.core.meta import MetaSummarizer
from repro.core.fast_path import fast_path, VerifiedSeed, DEVICE_CONSERVATIVE
from repro.core.slow_path import (SlowPathConfig, SearchResult, slow_path,
                                  transfer_seeds)

__all__ = [
    "Directive", "CONSERVATIVE", "DIMENSIONS", "EXPERT_SYSTEMS", "TUNABLES",
    "violations", "is_valid", "random_directive", "enumerate_valid",
    "directive_key", "directive_from_dict",
    "V5E", "ChipSpec", "HardwareContext", "extract_hardware_context",
    "RooflineReport", "parse_collectives", "per_tile_exposed_s",
    "roofline_from_compiled", "window_stall_factor",
    "CostBreakdown", "CostSegment",
    "ScheduleProbe", "Timeline", "TraceWriter", "schedule_timeline",
    "validate_trace",
    "EvalRecord", "MetricsRegistry", "SearchTelemetry", "wallclock_us",
    "CollectiveSchedule", "BroadcastSchedule", "DispatchSchedule",
    "RingSchedule", "SendWindow", "check_live", "make_broadcast_schedule",
    "make_ring_schedule", "make_schedule", "respill_counts", "sanitize_tile",
    "send_window_depths",
    "CHECKS", "MUTATION_CLASSES", "Op", "Program", "VerifyError",
    "VerifyReport", "apply_mutation", "degrade_errors",
    "directive_programs", "lower_schedule", "mutation_corpus",
    "verify_directive", "verify_program", "verify_schedule",
    "FaultPlan", "FaultSpec", "fault_cost", "inject_wire_fault",
    "survival_report",
    "analyze_comm_graph", "Candidate", "CascadeEvaluator", "EvalResult",
    "CandidateDB", "StoreError", "embed_code", "MapElitesArchive",
    "HeuristicMutator",
    "LLMMutator", "MutationContext", "parse_directive", "MetaSummarizer",
    "fast_path", "VerifiedSeed", "DEVICE_CONSERVATIVE", "SlowPathConfig",
    "SearchResult", "slow_path", "transfer_seeds",
]
