"""Slow-path agent: multi-island evolutionary search, Algorithm 1 (paper
§3.3, Appendix E/H) with explore->exploit phase scheduling, MAP-Elites
cross-pollination, embedding-guided candidate DB with novelty filtering,
periodic migration, and the meta-summarizer feedback loop.

Scaled search (docs/search.md — ROADMAP open item 3): each generation
proposes all islands' children first — against the end-of-previous-
generation db/archive state, with an intra-generation ``pending`` key set
standing in for the novelty the not-yet-folded siblings would provide —
then evaluates them as one batch, then folds results in island order. The
proposal/evaluate/fold phases are identical whether evaluation runs
sequentially or through ``CascadeEvaluator.evaluate_batch`` (the
``batched=`` flag), so the two modes produce the same ``db.history()`` and
byte-identical telemetry payloads by construction.

Warm start: ``slow_path(..., warm_start=path)`` loads a persisted
``CandidateDB`` or ``MapElitesArchive`` store. If the store's workload +
hardware fingerprints match this run's, generation zero is seeded from the
loaded elites, the archive is pre-populated with them (resumed coverage
can only grow), and any directive already evaluated in the store is served
from cache instead of re-running the cascade (cache key =
``directive_key`` scoped by the two fingerprints). A mismatched store
falls back to :func:`transfer_seeds` — elite directives mapped onto the
target workload's tunable grids, validity-repaired, and re-evaluated from
scratch. A corrupt or version-mismatched store degrades to a clean cold
start. ``save_to=path`` persists the finished run's db for the next one.
"""
from __future__ import annotations

import dataclasses
import math
import random
from dataclasses import dataclass, field

from repro.core.archive import MapElitesArchive
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.database import CandidateDB
from repro.core.design_space import TUNABLES, Directive, directive_key, \
    is_valid, random_directive
from repro.core.meta import MetaSummarizer
from repro.core.mutation import HeuristicMutator, MutationContext

# the tile-shaped knob alias family (all sanitized by
# core/schedule.py::sanitize_tile at the consumer boundary): a tuned value
# for any of these carries a transferable "preferred tile size" signal
# that transfer_seeds maps onto whichever of them the target workload has.
TILE_KNOBS = ("block_tokens", "combine_tile", "kv_chunk", "tile_m")


@dataclass
class SlowPathConfig:
    islands: int = 3
    generations: int = 12
    explore_frac: float = 0.4        # paper §4.4: 40% explore then exploit
    migration_every: int = 4
    migration_k: int = 1
    selection_pressure: float = 2.0
    seed: int = 0
    meta_every: int = 3


@dataclass
class Island:
    idx: int
    population: list = field(default_factory=list)

    def select(self, rng, pressure):
        """Fitness-weighted sampling (softmax over score with pressure)."""
        alive = [c for c in self.population if c.result is not None]
        if not alive:
            return None
        mx = max(c.score for c in alive)
        ws = [math.exp(pressure * (c.score - mx) / max(1.0, mx or 1.0))
              for c in alive]
        return rng.choices(alive, weights=ws)[0]


@dataclass
class SearchResult:
    best: Candidate
    db: CandidateDB
    archive: MapElitesArchive
    meta: MetaSummarizer
    seed_score: float
    history: list
    # per-generation/per-island series + mutation win rates aggregated from
    # the cascade's EvalRecords (core/telemetry.py::SearchTelemetry); the
    # source of the BENCH_search.json artifact
    telemetry: object = None

    def best_per_generation(self):
        out = {}
        for r in self.db.records:
            if r.result and r.result.ok:
                out[r.gen] = max(out.get(r.gen, 0.0), r.score)
        best = 0.0
        series = []
        for g in sorted(out):
            best = max(best, out[g])
            series.append((g, best))
        return series


def slow_path(seed, mesh, hw, cfg: SlowPathConfig = None, *,
              mutator=None, evaluator=None, verbose=False, batched=False,
              eval_workers=None, warm_start=None,
              save_to=None) -> SearchResult:
    """seed: VerifiedSeed from the fast path (generation zero).

    ``batched=True`` routes each generation's evaluations through
    ``evaluator.evaluate_batch`` (``eval_workers`` bounds the pool);
    ``warm_start``/``save_to`` load/persist the search store (module
    docstring)."""
    cfg = cfg or SlowPathConfig()
    rng = random.Random(cfg.seed)
    wl = seed.workload
    ev = evaluator or CascadeEvaluator(wl, mesh, hw)
    mut = mutator or HeuristicMutator()
    db = CandidateDB()
    archive = MapElitesArchive()
    meta = MetaSummarizer(every=cfg.meta_every)
    traits = wl.traits(hw)
    tun_space = _tunable_space(wl)
    scale = {"warm_start": False, "cache_hits": 0, "transferred_seeds": 0}

    warm = _load_warm_start(warm_start, wl, hw) if warm_start else None
    cache = warm["cache"] if warm else {}
    if warm:
        scale["warm_start"] = True
        scale["transferred_seeds"] = warm["transferred"]
        for c in warm["prewarm"]:      # saved cells re-offered: coverage
            archive.offer(c)           # resumes >= where it left off

    def eval_all(cands):
        """The one evaluation point for a proposed generation: cache hits
        (warm start) are served without touching the evaluator; misses run
        sequentially or as one bounded-pool batch — result streams
        identical either way (cascade parity contract)."""
        misses = []
        for c in cands:
            hit = cache.get(directive_key(c.directive))
            if hit is not None:
                c.result = dataclasses.replace(hit)
                c.cached = True
                scale["cache_hits"] += 1
            else:
                misses.append(c)
        if batched and hasattr(ev, "evaluate_batch"):
            for c, r in zip(misses,
                            ev.evaluate_batch(misses,
                                              max_workers=eval_workers)):
                c.result = r
        else:
            for c in misses:
                c.result = ev.evaluate(c)

    # island initialization: distinct seeds = semantically different variants
    # of the fast-path baseline drawn from C (paper Appendix E); a warm
    # start replaces the random variants with loaded/transferred elites
    # (which keep their own tuned tunables)
    islands = [Island(idx=i) for i in range(cfg.islands)]
    gen0 = []
    warm_seeds = list(warm["seeds"]) if warm else []
    used = {directive_key(seed.directive)}
    for isl in islands:
        label = "island-seed"
        if isl.idx == 0:
            d = seed.directive
        else:
            d = None
            while warm_seeds:
                s = warm_seeds.pop(0)
                if directive_key(s) not in used:
                    d = s
                    label = "transfer-seed" if warm["transferred"] \
                        else "warm-seed"
                    break
            if d is None:
                d = random_directive(rng, **traits)
        if label == "island-seed":
            d = dataclasses.replace(d, tunables=seed.directive.tunables)
        used.add(directive_key(d))
        gen0.append(Candidate(directive=d, gen=0, island=isl.idx,
                              mutation=label))
    eval_all(gen0)
    for isl, cand in zip(islands, gen0):
        db.add(cand)
        archive.offer(cand)
        meta.observe(cand)
        isl.population.append(cand)
    seed_score = islands[0].population[0].score
    coverage = {0: archive.coverage()}     # per-gen archive coverage series

    recommendations = []
    for gen in range(1, cfg.generations + 1):
        phase = "explore" if gen <= cfg.explore_frac * cfg.generations \
            else "exploit"
        # -- propose: every island's child, against end-of-last-generation
        # state; ``pending`` carries intra-generation novelty
        proposals = []
        pending = set()
        for isl in islands:
            parent = isl.select(rng, cfg.selection_pressure)
            if parent is None:
                continue
            ctx = MutationContext(
                parent=parent, phase=phase,
                archive_samples=archive.sample(
                    rng, 2, exclude_behavior=parent.directive.behavior),
                neighbors=db.knn(parent, 3),
                recommendations=recommendations,
                hardware=hw, traits=traits, tunable_space=tun_space)
            d, form = mut.propose(ctx, rng)
            if not db.is_novel(d) or directive_key(d) in pending:
                d, form = mut.propose(ctx, rng)    # novelty filter: resample
                if not db.is_novel(d) or directive_key(d) in pending:
                    d = random_directive(rng, **traits)
                    form = "novelty-resample"
            pending.add(directive_key(d))
            proposals.append(
                (isl, Candidate(directive=d, gen=gen, island=isl.idx,
                                parent_id=parent.cid, mutation=form)))
        # -- evaluate: the whole generation at once (cascade l1 -> l2 -> l3)
        eval_all([child for _, child in proposals])
        # -- fold in: island order, exactly as the sequential loop did
        for isl, child in proposals:
            db.add(child)
            archive.offer(child)
            meta.observe(child)
            isl.population.append(child)
            if len(isl.population) > 8:            # bounded population
                isl.population.sort(key=lambda c: -c.score)
                isl.population = isl.population[:8]
            if verbose:
                print(f"g{gen} i{isl.idx} {child.mutation:16s} "
                      f"{child.directive.backend[:5]}/"
                      f"{child.directive.placement[:14]} "
                      f"score={child.score:8.2f} [{phase}]")
        # migration: top-k of each island copied into a random other island
        if gen % cfg.migration_every == 0:
            for isl in islands:
                top = sorted(isl.population, key=lambda c: -c.score)
                for t in top[:cfg.migration_k]:
                    dst = rng.choice([j for j in islands if j.idx != isl.idx])
                    dst.population.append(t)
        if gen % cfg.meta_every == 0:
            _, recommendations = meta.summarize(gen, db)
        coverage[gen] = archive.coverage()

    best = db.best
    from repro.core.telemetry import SearchTelemetry
    telemetry = SearchTelemetry.from_candidates(
        db.records, workload=wl.name, coverage=coverage)
    telemetry.note_scale(**scale)
    if save_to:
        db.save(save_to, workload=wl.fingerprint(), hardware=hw.fingerprint)
    return SearchResult(best=best, db=db, archive=archive, meta=meta,
                        seed_score=seed_score, history=db.history(),
                        telemetry=telemetry)


# -------------------------------------------------- warm start and transfer


def _load_warm_start(path, wl, hw):
    """Resolve a warm-start store into gen-0 seeds, an eval cache, and
    archive pre-population. Accepts either store kind (db or archive).
    Returns ``None`` — a clean cold start — when the store is missing,
    corrupt, version-mismatched, or empty; the search must never die on a
    bad store it was merely offered."""
    try:
        from repro.core.database import StoreError
        try:
            store_db = CandidateDB.load(path)
            meta_fp = store_db.saved_meta
            elite_arch = MapElitesArchive()
            for r in store_db.records:
                elite_arch.offer(r)
            cache_src = [r for r in store_db.records if r.result is not None]
        except StoreError:
            elite_arch = MapElitesArchive.load(path)
            meta_fp = elite_arch.saved_meta
            cache_src = list(elite_arch.cells.values())
        elites = elite_arch.elites()
        matched = (meta_fp.get("workload") == wl.fingerprint()
                   and meta_fp.get("hardware") == hw.fingerprint)
        if matched:
            seeds = [c.directive for c in elites]
            cache = {directive_key(c.directive): c.result
                     for c in cache_src}
            prewarm, transferred = elites, 0
        else:
            seeds = transfer_seeds(elite_arch, wl, hw=hw)
            cache, prewarm, transferred = {}, [], len(seeds)
        if not seeds:
            return None
        return {"seeds": seeds, "cache": cache, "prewarm": prewarm,
                "transferred": transferred}
    except Exception:
        return None


def transfer_seeds(archive, target_wl, hw=None, limit=None):
    """Map a tuned archive's elites onto another workload (docs/search.md):
    for each elite, keep every tunable the target also exposes, carry the
    elite's tile-size signal across the ``sanitize_tile`` alias family
    (``block_tokens``/``combine_tile``/``kv_chunk``/``tile_m`` — snapped to
    the target knob's grid), fill the rest from the target's defaults, and
    validity-repair the dimensions against the target's traits with a
    fixed substitution ladder. Deduped by ``directive_key``, ordered by
    source score. These seed generation zero of a cross-workload warm
    start; they are always re-evaluated (a cached score never crosses a
    fingerprint boundary)."""
    traits = target_wl.traits(hw)
    defaults = target_wl.default_tunables()
    out, seen = [], set()
    for elite in archive.elites():
        src = dict(elite.directive.tunables)
        tile = next((src[n] for n in TILE_KNOBS
                     if isinstance(src.get(n), int)), None)
        tun = {}
        for name, dv in sorted(defaults.items()):
            if name in src:
                tun[name] = src[name]
            elif name in TILE_KNOBS and tile is not None:
                tun[name] = _snap(tile, TUNABLES.get(name))
            elif dv is not None:
                tun[name] = dv
        d = dataclasses.replace(elite.directive,
                                tunables=tuple(sorted(tun.items())))
        d = _repair(d, traits)
        k = directive_key(d)
        if k in seen:
            continue
        seen.add(k)
        out.append(d)
        if limit and len(out) >= limit:
            break
    return out


def _snap(value, grid):
    """Nearest grid point (deterministic: ties go to the smaller knob)."""
    if not grid:
        return value
    return min(grid, key=lambda g: (abs(g - value), g))


def _repair(d: Directive, traits) -> Directive:
    """Deterministic validity ladder for a transferred directive: try the
    mapped point, then progressively safer substitutions, ending at the
    always-valid conservative coordinates (tunables kept throughout)."""
    trials = (
        d,
        dataclasses.replace(d, scope="LOCAL"),
        dataclasses.replace(d, scope="LOCAL", granularity="PER_TILE"),
        dataclasses.replace(d, scope="LOCAL", granularity="PER_TILE",
                            contexts=max(2, d.contexts)),
        dataclasses.replace(d, backend="XLA_COLLECTIVE",
                            completion="BARRIER", placement="DEFERRED",
                            issuer="KERNEL", scope="WORLD",
                            granularity="PER_PEER", ordering="RELEASE",
                            contexts=1),
    )
    for t in trials:
        if is_valid(t, **traits):
            return t
    return trials[-1]


def _tunable_space(wl):
    """Diff-patch candidate grids: the central design-space registry for
    known knobs (block_tokens, combine_tile, tile_m, kv_chunk, chained,
    tight, wire_i8 — any workload ``default_tunables()`` name found in
    ``TUNABLES``), a geometric grid for workload-specific integers, plus
    the ``contexts`` dimension mirror — always refinable, so fine-grained
    mutations can retune the send-window depth of a kernelized point
    without a placement move. Tile-shaped knobs are sanitized by their
    consumers (``core/schedule.py::sanitize_tile``), so any grid value is
    safe to propose."""
    defaults = wl.default_tunables()
    space = {}
    for name, v in defaults.items():
        if name in TUNABLES:
            space[name] = TUNABLES[name]
        elif isinstance(v, int) and v > 1:
            space[name] = tuple(sorted({max(1, v // 4), max(1, v // 2), v,
                                        v * 2, v * 4}))
    space.setdefault("contexts", TUNABLES["contexts"])
    return space
