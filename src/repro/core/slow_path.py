"""Slow-path agent: multi-island evolutionary search, Algorithm 1 (paper
§3.3, Appendix E/H) with explore->exploit phase scheduling, MAP-Elites
cross-pollination, embedding-guided candidate DB with novelty filtering,
periodic migration, and the meta-summarizer feedback loop."""
from __future__ import annotations

import dataclasses
import math
import random
from dataclasses import dataclass, field

from repro.core.archive import MapElitesArchive
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.database import CandidateDB
from repro.core.design_space import TUNABLES, Directive, random_directive
from repro.core.meta import MetaSummarizer
from repro.core.mutation import HeuristicMutator, MutationContext


@dataclass
class SlowPathConfig:
    islands: int = 3
    generations: int = 12
    explore_frac: float = 0.4        # paper §4.4: 40% explore then exploit
    migration_every: int = 4
    migration_k: int = 1
    selection_pressure: float = 2.0
    seed: int = 0
    meta_every: int = 3


@dataclass
class Island:
    idx: int
    population: list = field(default_factory=list)

    def select(self, rng, pressure):
        """Fitness-weighted sampling (softmax over score with pressure)."""
        alive = [c for c in self.population if c.result is not None]
        if not alive:
            return None
        mx = max(c.score for c in alive)
        ws = [math.exp(pressure * (c.score - mx) / max(1.0, mx or 1.0))
              for c in alive]
        return rng.choices(alive, weights=ws)[0]


@dataclass
class SearchResult:
    best: Candidate
    db: CandidateDB
    archive: MapElitesArchive
    meta: MetaSummarizer
    seed_score: float
    history: list
    # per-generation/per-island series + mutation win rates aggregated from
    # the cascade's EvalRecords (core/telemetry.py::SearchTelemetry); the
    # source of the BENCH_search.json artifact
    telemetry: object = None

    def best_per_generation(self):
        out = {}
        for r in self.db.records:
            if r.result and r.result.ok:
                out[r.gen] = max(out.get(r.gen, 0.0), r.score)
        best = 0.0
        series = []
        for g in sorted(out):
            best = max(best, out[g])
            series.append((g, best))
        return series


def slow_path(seed, mesh, hw, cfg: SlowPathConfig = None, *,
              mutator=None, evaluator=None, verbose=False) -> SearchResult:
    """seed: VerifiedSeed from the fast path (generation zero)."""
    cfg = cfg or SlowPathConfig()
    rng = random.Random(cfg.seed)
    wl = seed.workload
    ev = evaluator or CascadeEvaluator(wl, mesh, hw)
    mut = mutator or HeuristicMutator()
    db = CandidateDB()
    archive = MapElitesArchive()
    meta = MetaSummarizer(every=cfg.meta_every)
    traits = wl.traits(hw)
    tun_space = _tunable_space(wl)

    # island initialization: distinct seeds = semantically different variants
    # of the fast-path baseline drawn from C (paper Appendix E)
    islands = []
    for i in range(cfg.islands):
        d = seed.directive if i == 0 else random_directive(rng, **traits)
        d = dataclasses.replace(
            d, tunables=seed.directive.tunables)
        cand = Candidate(directive=d, gen=0, island=i,
                         mutation="island-seed")
        cand.result = ev.evaluate(cand)
        db.add(cand)
        archive.offer(cand)
        meta.observe(cand)
        islands.append(Island(idx=i, population=[cand]))
    seed_score = islands[0].population[0].score
    coverage = {0: archive.coverage()}     # per-gen archive coverage series

    recommendations = []
    for gen in range(1, cfg.generations + 1):
        phase = "explore" if gen <= cfg.explore_frac * cfg.generations \
            else "exploit"
        for isl in islands:
            parent = isl.select(rng, cfg.selection_pressure)
            if parent is None:
                continue
            ctx = MutationContext(
                parent=parent, phase=phase,
                archive_samples=archive.sample(
                    rng, 2, exclude_behavior=parent.directive.behavior),
                neighbors=db.knn(parent, 3),
                recommendations=recommendations,
                hardware=hw, traits=traits, tunable_space=tun_space)
            d, form = mut.propose(ctx, rng)
            if not db.is_novel(d):                 # novelty filter: resample
                d, form = mut.propose(ctx, rng)
                if not db.is_novel(d):
                    d = random_directive(rng, **traits)
                    form = "novelty-resample"
            child = Candidate(directive=d, gen=gen, island=isl.idx,
                              parent_id=parent.cid, mutation=form)
            child.result = ev.evaluate(child)      # cascade l1 -> l2 -> l3
            db.add(child)
            archive.offer(child)
            meta.observe(child)
            isl.population.append(child)
            if len(isl.population) > 8:            # bounded population
                isl.population.sort(key=lambda c: -c.score)
                isl.population = isl.population[:8]
            if verbose:
                print(f"g{gen} i{isl.idx} {form:16s} "
                      f"{d.backend[:5]}/{d.placement[:14]} "
                      f"score={child.score:8.2f} [{phase}]")
        # migration: top-k of each island copied into a random other island
        if gen % cfg.migration_every == 0:
            for isl in islands:
                top = sorted(isl.population, key=lambda c: -c.score)
                for t in top[:cfg.migration_k]:
                    dst = rng.choice([j for j in islands if j.idx != isl.idx])
                    dst.population.append(t)
        if gen % cfg.meta_every == 0:
            _, recommendations = meta.summarize(gen, db)
        coverage[gen] = archive.coverage()

    best = db.best
    from repro.core.telemetry import SearchTelemetry
    telemetry = SearchTelemetry.from_candidates(
        db.records, workload=wl.name, coverage=coverage)
    return SearchResult(best=best, db=db, archive=archive, meta=meta,
                        seed_score=seed_score, history=db.history(),
                        telemetry=telemetry)


def _tunable_space(wl):
    """Diff-patch candidate grids: the central design-space registry for
    known knobs (block_tokens, combine_tile, tile_m, kv_chunk, chained,
    tight, wire_i8 — any workload ``default_tunables()`` name found in
    ``TUNABLES``), a geometric grid for workload-specific integers, plus
    the ``contexts`` dimension mirror — always refinable, so fine-grained
    mutations can retune the send-window depth of a kernelized point
    without a placement move. Tile-shaped knobs are sanitized by their
    consumers (``core/schedule.py::sanitize_tile``), so any grid value is
    safe to propose."""
    defaults = wl.default_tunables()
    space = {}
    for name, v in defaults.items():
        if name in TUNABLES:
            space[name] = TUNABLES[name]
        elif isinstance(v, int) and v > 1:
            space[name] = tuple(sorted({max(1, v // 4), max(1, v // 2), v,
                                        v * 2, v * 4}))
    space.setdefault("contexts", TUNABLES["contexts"])
    return space
