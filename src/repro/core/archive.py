"""MAP-Elites diversity archive (paper Appendix E, Mouret & Clune 2015).

Grid indexed by the behavioral descriptor derived from the optimization
directive (backend, placement, completion); each cell keeps the
highest-scoring candidate with that behavioral profile. Archive samples are
injected into mutation prompts as cross-pollination inspirations."""
from __future__ import annotations

import random


class MapElitesArchive:
    def __init__(self):
        self.cells = {}

    def offer(self, cand):
        key = cand.directive.behavior
        cur = self.cells.get(key)
        if cand.result and cand.result.ok and (cur is None
                                               or cand.score > cur.score):
            self.cells[key] = cand
            return True
        return False

    def sample(self, rng: random.Random, k=2, exclude_behavior=None):
        pool = [c for b, c in self.cells.items() if b != exclude_behavior]
        rng.shuffle(pool)
        return pool[:k]

    def elites(self):
        return sorted(self.cells.values(), key=lambda c: -c.score)

    def coverage(self):
        return len(self.cells)
