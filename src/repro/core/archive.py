"""MAP-Elites diversity archive (paper Appendix E, Mouret & Clune 2015).

Grid indexed by the behavioral descriptor derived from the optimization
directive (backend, placement, completion); each cell keeps the
highest-scoring candidate with that behavioral profile. Archive samples are
injected into mutation prompts as cross-pollination inspirations.

The archive also persists (docs/search.md): :meth:`MapElitesArchive.save`
writes each cell's behavior key, elite candidate (directive + deterministic
result fields) and code embedding as versioned JSON;
:meth:`MapElitesArchive.load` rebuilds it, raising
``database.StoreError`` on corruption or a version this code does not
read. ``slow_path(..., warm_start=...)`` accepts either store kind."""
from __future__ import annotations

import json
import random

ARCHIVE_SCHEMA = "cuco-map-elites"
ARCHIVE_VERSION = 1


class MapElitesArchive:
    def __init__(self):
        self.cells = {}

    def offer(self, cand):
        key = cand.directive.behavior
        cur = self.cells.get(key)
        if cand.result and cand.result.ok and (cur is None
                                               or cand.score > cur.score):
            self.cells[key] = cand
            return True
        return False

    def sample(self, rng: random.Random, k=2, exclude_behavior=None):
        pool = [c for b, c in self.cells.items() if b != exclude_behavior]
        rng.shuffle(pool)
        return pool[:k]

    def elites(self):
        return sorted(self.cells.values(), key=lambda c: -c.score)

    def coverage(self):
        return len(self.cells)

    # ------------------------------------------------------------ persistence
    def save(self, path, *, workload="", hardware=""):
        """Versioned JSON of every cell: behavior key, elite candidate, and
        its code embedding, stamped with the fingerprints the elites were
        scored under (cells sorted by behavior for a deterministic file)."""
        from repro.core.database import candidate_to_dict, embed_code
        cells = []
        for behavior in sorted(self.cells):
            cand = self.cells[behavior]
            emb = embed_code(cand.code_text or cand.directive.render())
            cells.append({"behavior": list(behavior),
                          "candidate": candidate_to_dict(cand),
                          "embedding": [round(float(x), 7) for x in emb]})
        payload = {"schema": ARCHIVE_SCHEMA, "version": ARCHIVE_VERSION,
                   "workload": str(workload), "hardware": str(hardware),
                   "cells": cells}
        with open(path, "w") as f:
            json.dump(payload, f, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path) -> "MapElitesArchive":
        """Rebuild an archive from :meth:`save` output; fingerprints land on
        ``archive.saved_meta``. Raises ``database.StoreError`` on corruption
        or version mismatch."""
        from repro.core.database import StoreError, candidate_from_dict, \
            load_store
        payload = load_store(path, ARCHIVE_SCHEMA, ARCHIVE_VERSION)
        arch = cls()
        try:
            for cell in payload["cells"]:
                cand = candidate_from_dict(cell["candidate"])
                behavior = tuple(cell["behavior"])
                if behavior != cand.directive.behavior:
                    raise StoreError(
                        f"{path}: cell behavior {behavior} does not match "
                        f"its elite's directive {cand.directive.behavior}")
                arch.cells[behavior] = cand
        except StoreError:
            raise
        except (KeyError, TypeError, ValueError) as e:
            raise StoreError(f"{path}: malformed archive cell: {e}") from e
        arch.saved_meta = {"workload": payload.get("workload", ""),
                           "hardware": payload.get("hardware", "")}
        return arch
