"""The structured co-design configuration space C (paper §3.1, Appendix B),
adapted to TPU (DESIGN.md §2).

C = B x M x P x S x I x G x O x K

Concrete dimensions map to real JAX/Pallas mechanisms; intent dimensions are
realized by the workload builders. Expert-crafted systems are points in this
space (paper Table 3) — reproduced below with their TPU-adapted coordinates.

The agents never emit free-form programs: a candidate IS a Directive (+ its
numeric tunables), and the workload's builder realizes it. This is the
paper's core claim — "LLMs as bounded operators over domain-defined search
spaces" — with the bounding enforced by construction.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, replace

# ---------------------------------------------------------------- dimensions

BACKENDS = ("XLA_COLLECTIVE", "PALLAS_RDMA", "HYBRID")
# paper: GIN | LSA | Hybrid.  TPU: XLA-level collectives (host-driven
# analogue is "deferred XLA collective"), Pallas remote DMA (device-initiated
# — the GIN analogue; same-ICI-domain neighbor stores are the closest LSA
# analogue), HYBRID = Pallas intra-pod + XLA cross-pod.

COMPLETIONS = ("BARRIER", "SIGNAL", "SIGNAL_SHADOW", "COUNTER")
# BARRIER  -> global semaphore barrier after transfers
# SIGNAL   -> per-edge DMA recv semaphores (point-to-point wait)
# SIGNAL_SHADOW -> signal + locally-cached count (skip re-polling)
# COUNTER  -> SMEM/atomic counters for intra-kernel per-tile readiness

PLACEMENTS = ("DEFERRED", "TILE_FUSED", "TILE_PIPELINED", "STREAM_SPLIT")
# DEFERRED  -> comm strictly after compute (host-driven shape)
# TILE_FUSED -> comm issued inside the compute kernel per tile
# TILE_PIPELINED -> DMA for tile j+1 in flight while computing tile j
# STREAM_SPLIT -> dependence-free XLA scheduling (async collective overlaps
#                 an independent compute chain — the two-stream analogue)

SCOPES = ("LOCAL", "WORLD", "RAIL", "HIERARCHICAL")
# LOCAL -> intra-pod (ICI domain); WORLD -> all chips incl. DCN;
# RAIL -> same mesh row/col; HIERARCHICAL -> intra-pod then cross-pod phases

ISSUERS = ("KERNEL", "GRID_STEP", "CHUNKED")
# TPU has no warps/CTAs: the issuer is the loop level that starts the DMA —
# once per kernel, once per grid step (per tile), or per sub-chunk.

GRANULARITIES = ("PER_PEER", "PER_TILE", "PER_CHUNK")

ORDERINGS = ("RELAXED", "ACQUIRE", "RELEASE", "ACQREL")
# TPU reading: where semaphore waits sit relative to compute. RELAXED =
# defer waits to the last moment (max reordering), RELEASE = sender flushes
# before signaling, ACQUIRE = receiver waits before any dependent read,
# ACQREL = both (fully eager waits).

CONTEXTS = (1, 2, 4)
# number of in-flight communication buffers (double/quad buffering depth)

# ------------------------------------------------- numeric tunable space
# Central candidate grids for the slow path's diff-patch (exploit) mutation
# form: these refine *within* a behavior cell of the archive. Workloads
# whose default_tunables() name one of these knobs get the grid below;
# ``contexts`` mirrors the directive dimension so a fine-grained diff can
# retune the send-window depth without a placement move.
TUNABLES = {
    "block_tokens": (16, 32, 64, 128, 256),   # microblock rows per DMA round
    "chained": (0, 1),                        # kv_shuttle K→V signal chain
    "combine_tile": (8, 16, 32, 64, 128),     # fused-combine GEMM tile rows
    "contexts": CONTEXTS,                     # in-flight send window depth
    "kv_chunk": (16, 32, 64, 128, 256),       # ring rotation chunk rows
    "tight": (0, 1),                          # exact vs padded wire sizes
    "tile_m": (16, 32, 64, 128, 256),         # gemm_allgather GEMM tile rows
    "wire_i8": (0, 1),                        # int8 dispatch wire
}
# grid values need not divide a given workload shape: consumers sanitize at
# their own boundary (core/schedule.py::sanitize_tile and its per-knob
# aliases) so a diff-patch mutation can never crash the evaluator.

DIMENSIONS = {
    "backend": BACKENDS,
    "completion": COMPLETIONS,
    "placement": PLACEMENTS,
    "scope": SCOPES,
    "issuer": ISSUERS,
    "granularity": GRANULARITIES,
    "ordering": ORDERINGS,
    "contexts": CONTEXTS,
}


@dataclass(frozen=True)
class Directive:
    """One point in C. Emitted by every agent BEFORE any code is built
    (paper Appendix G) — making design decisions inspectable."""
    backend: str = "XLA_COLLECTIVE"
    completion: str = "BARRIER"
    placement: str = "DEFERRED"
    scope: str = "WORLD"
    issuer: str = "KERNEL"
    granularity: str = "PER_PEER"
    ordering: str = "RELEASE"
    contexts: int = 1
    # numeric tunables refined by diff-patch mutations
    tunables: tuple = ()             # sorted ((name, value), ...)

    def tunable(self, name, default=None):
        return dict(self.tunables).get(name, default)

    def with_tunable(self, name, value):
        d = dict(self.tunables)
        d[name] = value
        return replace(self, tunables=tuple(sorted(d.items())))

    def as_dict(self):
        d = {k: getattr(self, k) for k in DIMENSIONS}
        d["tunables"] = dict(self.tunables)
        return d

    def render(self):
        """The literal optimization-directive block (paper Appendix G)."""
        lines = ["OPTIMIZATION DIRECTIVE"]
        for k in DIMENSIONS:
            lines.append(f"  {k:12s} = {getattr(self, k)}")
        for n, v in self.tunables:
            lines.append(f"  tunable {n} = {v}")
        return "\n".join(lines)

    @property
    def behavior(self):
        """MAP-Elites behavioral descriptor (backend, placement, completion)."""
        return (self.backend, self.placement, self.completion)


def directive_key(d: Directive) -> str:
    """Canonical identity of a point in C: the ``as_dict`` form, JSON-encoded
    with sorted keys. Two directives that realize the same configuration map
    to the same key regardless of tunables-tuple ordering — this is the
    novelty-filter index key (``core/database.py``) and, combined with the
    workload + hardware fingerprints, the warm-start eval-cache key
    (docs/search.md)."""
    import json
    return json.dumps(d.as_dict(), sort_keys=True)


def directive_from_dict(obj: dict) -> Directive:
    """Inverse of :meth:`Directive.as_dict` — the persistence decoder for
    ``CandidateDB.load`` / ``MapElitesArchive.load``."""
    kw = {k: obj[k] for k in DIMENSIONS}
    kw["contexts"] = int(kw["contexts"])
    tun = obj.get("tunables", {})
    return Directive(**kw, tunables=tuple(sorted(tun.items())))


CONSERVATIVE = Directive(
    backend="XLA_COLLECTIVE", completion="BARRIER", placement="DEFERRED",
    scope="WORLD", issuer="KERNEL", granularity="PER_PEER",
    ordering="RELEASE", contexts=1,
)
# The fast-path agent always emits this fixed conservative directive (§3.2):
# deterministic, collective-semantic, zero overlap — correctness first.


# -------------------------------------------------- validity (bounded space)

def violations(d: Directive, *, has_dcn=False, kernelizable=True,
               ring_topology=False) -> list:
    """Semantic constraints that bound the agents' degrees of freedom.
    An empty list means the directive is realizable for the workload/hardware.
    """
    v = []
    if d.backend not in BACKENDS:
        v.append(f"unknown backend {d.backend}")
    if d.completion not in COMPLETIONS or d.placement not in PLACEMENTS \
            or d.scope not in SCOPES or d.issuer not in ISSUERS \
            or d.granularity not in GRANULARITIES or d.ordering not in ORDERINGS:
        v.append("unknown dimension value")
    if d.contexts not in CONTEXTS:
        v.append(f"contexts must be one of {CONTEXTS}")
    if d.backend == "XLA_COLLECTIVE":
        if d.completion in ("SIGNAL", "SIGNAL_SHADOW", "COUNTER"):
            v.append("XLA collectives are barrier-semantic: point-to-point "
                     "completion requires PALLAS_RDMA")
        if d.placement in ("TILE_FUSED", "TILE_PIPELINED"):
            v.append("in-kernel placement requires PALLAS_RDMA backend")
        if d.issuer != "KERNEL":
            v.append("XLA collectives are issued once per op (KERNEL issuer)")
    if d.backend in ("PALLAS_RDMA", "HYBRID"):
        if not kernelizable:
            v.append("workload has no Pallas kernelization")
        if d.placement == "DEFERRED" and d.completion == "COUNTER":
            v.append("COUNTER completion only meaningful inside a fused kernel")
    if d.backend == "PALLAS_RDMA" and has_dcn and d.scope == "WORLD":
        v.append("Pallas RDMA is ICI-only: WORLD scope across DCN requires "
                 "HYBRID or XLA_COLLECTIVE")
    if d.placement == "TILE_PIPELINED" and d.contexts < 2:
        v.append("pipelined placement needs >=2 buffers (contexts)")
    if d.placement in ("TILE_FUSED", "TILE_PIPELINED") \
            and d.granularity == "PER_PEER" and ring_topology:
        v.append("fused ring kernels exchange PER_TILE/PER_CHUNK, not PER_PEER")
    if d.completion == "COUNTER" and d.placement not in ("TILE_FUSED",):
        v.append("COUNTER requires TILE_FUSED placement")
    if d.scope == "HIERARCHICAL" and not has_dcn:
        v.append("HIERARCHICAL scope needs a multi-pod mesh")
    return v


def is_valid(d: Directive, **traits) -> bool:
    return not violations(d, **traits)


def random_directive(rng: random.Random, **traits) -> Directive:
    """Rejection-sample a valid directive (bounded-operator fallback)."""
    for _ in range(200):
        d = Directive(
            backend=rng.choice(BACKENDS),
            completion=rng.choice(COMPLETIONS),
            placement=rng.choice(PLACEMENTS),
            scope=rng.choice(SCOPES),
            issuer=rng.choice(ISSUERS),
            granularity=rng.choice(GRANULARITIES),
            ordering=rng.choice(ORDERINGS),
            contexts=rng.choice(CONTEXTS),
        )
        if is_valid(d, **traits):
            return d
    return CONSERVATIVE


def enumerate_valid(**traits):
    for combo in itertools.product(BACKENDS, COMPLETIONS, PLACEMENTS, SCOPES,
                                   ISSUERS, GRANULARITIES, ORDERINGS, CONTEXTS):
        d = Directive(*combo)
        if is_valid(d, **traits):
            yield d


# ------------------------------------------- expert systems as points in C
# (paper Table 3, TPU-adapted coordinates)

EXPERT_SYSTEMS = {
    "DeepEP (NVL)": Directive("PALLAS_RDMA", "BARRIER", "DEFERRED", "LOCAL",
                              "KERNEL", "PER_PEER", "RELEASE", 1),
    "DeepEP (IB)": Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "WORLD",
                             "KERNEL", "PER_PEER", "ACQUIRE", 1),
    # FLUX / CoCoNet point: the GEMM tile loop fused with per-tile
    # communication — COUNTER readiness ticks per output tile
    "FLUX": Directive("PALLAS_RDMA", "COUNTER", "TILE_FUSED", "LOCAL",
                      "GRID_STEP", "PER_TILE", "ACQREL", 1),
    "TokenWeave": Directive("XLA_COLLECTIVE", "BARRIER", "STREAM_SPLIT",
                            "LOCAL", "KERNEL", "PER_CHUNK", "RELEASE", 2),
}
