"""Candidate database with code embeddings, kNN retrieval, novelty
filtering (paper Appendix E/J), and a persistent warm-start store.

The paper embeds candidate CUDA source with a neural code encoder; here the
"code" is the lowered program (jaxpr/StableHLO text) and the embedding is a
feature-hashed bag of op n-grams — deterministic, dependency-free, and good
enough for structural similarity (psum-heavy vs permute-heavy vs DMA-heavy
programs land far apart).

Novelty is indexed: every added record's :func:`~repro.core.design_space.
directive_key` lands in a set, so :meth:`CandidateDB.is_novel` is O(1) per
proposal instead of the former O(n) linear scan (quadratic over a whole
search). The key is the canonical ``as_dict`` identity — exactly the
equality the old scan tested (two directives whose rendered configuration
matches are "seen"), so accept/reject decisions are unchanged on any
proposal stream the bounded mutators emit.

Persistence (docs/search.md): :meth:`CandidateDB.save` /
:meth:`CandidateDB.load` serialize the full record stream (directives,
scores, levels, embeddings) as versioned JSON stamped with the workload +
hardware fingerprints, so a later ``slow_path(..., warm_start=path)`` can
seed generation zero from the store's elites and skip re-evaluating any
cached directive. A corrupted or version-mismatched store raises
:class:`StoreError`; the warm-start loader degrades that to a clean cold
start.
"""
from __future__ import annotations

import hashlib
import json
import re

import numpy as np

from repro.core.design_space import directive_from_dict, directive_key

_TOKEN_RE = re.compile(r"[a-zA-Z][\w\-.]*")
DIM = 128

DB_SCHEMA = "cuco-candidate-db"
DB_VERSION = 1


class StoreError(ValueError):
    """A persisted search store failed to load (corrupt JSON, wrong schema,
    or a version this code does not read). Warm-start treats this as a
    clean cold start; direct callers of ``load`` see the reason."""


def embed_code(text: str, dim: int = DIM) -> np.ndarray:
    toks = _TOKEN_RE.findall(text)[:20000]
    v = np.zeros(dim, np.float32)
    for i in range(len(toks) - 1):
        g = toks[i] + " " + toks[i + 1]
        h = int(hashlib.blake2s(g.encode(), digest_size=8).hexdigest(), 16)
        v[h % dim] += 1.0 if (h >> 63) else -1.0
    n = np.linalg.norm(v)
    return v / n if n else v


# --------------------------------------------------- candidate (de)serialize


def candidate_to_dict(cand) -> dict:
    """The persisted form of one evaluated candidate: the directive's
    canonical dict, its lineage, and the run-deterministic result fields
    (level/score/modeled ms — never wall timings). ``code_text`` stays out:
    the lowered jaxpr is hundreds of KB and rebuildable from the
    directive."""
    res = cand.result
    out = {
        "directive": cand.directive.as_dict(),
        "gen": int(cand.gen), "island": int(cand.island),
        "parent_id": int(cand.parent_id), "mutation": str(cand.mutation),
        "cid": int(cand.cid),
        "result": None,
    }
    if res is not None:
        t = res.t_model_ms
        out["result"] = {
            "level": int(res.level), "score": float(res.score),
            "t_model_ms": float(t) if np.isfinite(t) else None,
            "diagnostic": str(res.diagnostic),
            "quarantined": bool(res.quarantined),
            "retries": int(res.retries),
        }
    return out


def candidate_from_dict(obj: dict):
    """Inverse of :func:`candidate_to_dict`."""
    from repro.core.cascade import Candidate, EvalResult
    cand = Candidate(directive=directive_from_dict(obj["directive"]),
                     gen=int(obj["gen"]), island=int(obj["island"]),
                     parent_id=int(obj["parent_id"]),
                     mutation=str(obj["mutation"]), cid=int(obj["cid"]))
    r = obj.get("result")
    if r is not None:
        t = r.get("t_model_ms")
        cand.result = EvalResult(
            level=int(r["level"]), score=float(r["score"]),
            t_model_ms=float("inf") if t is None else float(t),
            diagnostic=str(r.get("diagnostic", "")),
            quarantined=bool(r.get("quarantined", False)),
            retries=int(r.get("retries", 0)))
    return cand


def load_store(path, schema: str, version: int) -> dict:
    """Read + validate one versioned JSON store; raises StoreError on any
    corruption or schema/version mismatch (shared by db and archive)."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        raise StoreError(f"unreadable store {path}: {e}") from e
    if not isinstance(payload, dict) or payload.get("schema") != schema:
        raise StoreError(f"{path}: not a {schema} store "
                         f"(schema={payload.get('schema')!r})"
                         if isinstance(payload, dict)
                         else f"{path}: not a JSON object")
    if payload.get("version") != version:
        raise StoreError(f"{path}: {schema} version "
                         f"{payload.get('version')!r} != {version}")
    return payload


class CandidateDB:
    def __init__(self, novelty_threshold: float = 0.995):
        self.records = []              # Candidate list (cid == index)
        self.embeddings = []
        self.novelty_threshold = novelty_threshold
        self._seen = set()             # directive_key of every record

    def add(self, cand):
        cand.cid = len(self.records)
        self.records.append(cand)
        self.embeddings.append(embed_code(cand.code_text or
                                          cand.directive.render()))
        self._seen.add(directive_key(cand.directive))
        return cand.cid

    def knn(self, cand, k=3):
        """Structurally similar prior candidates + their feedback."""
        if not self.records:
            return []
        q = embed_code(cand.code_text or cand.directive.render())
        sims = np.array([float(q @ e) for e in self.embeddings])
        order = np.argsort(-sims)
        out = []
        for i in order[:k + 1]:
            r = self.records[i]
            if r.cid == cand.cid:
                continue
            out.append((float(sims[i]), r))
        return out[:k]

    def is_novel(self, directive, code_text=""):
        """Novelty filter: reject configurations already seen. O(1) — the
        canonical ``directive_key`` of every added record is indexed in a
        set, replacing the former per-proposal linear scan (which also
        subsumes the old embedding branch: structural near-duplicates were
        only ever rejected when their ``as_dict`` matched a seen record's,
        and that is exactly key membership)."""
        return directive_key(directive) not in self._seen

    @property
    def best(self):
        done = [r for r in self.records if r.result and r.result.ok]
        return max(done, key=lambda r: r.score) if done else None

    def history(self):
        return [(r.cid, r.gen, r.island, r.mutation, r.score,
                 r.directive.behavior) for r in self.records]

    # ------------------------------------------------------------ persistence
    def save(self, path, *, workload="", hardware=""):
        """Write the versioned warm-start store: every record's directive +
        deterministic result fields + embedding, stamped with the workload
        and hardware fingerprints the scores were modeled under."""
        payload = {
            "schema": DB_SCHEMA, "version": DB_VERSION,
            "workload": str(workload), "hardware": str(hardware),
            "novelty_threshold": float(self.novelty_threshold),
            "records": [candidate_to_dict(c) for c in self.records],
            "embeddings": [[round(float(x), 7) for x in e]
                           for e in self.embeddings],
        }
        with open(path, "w") as f:
            json.dump(payload, f, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path) -> "CandidateDB":
        """Rebuild a db from :meth:`save` output; the store's fingerprints
        land on ``db.saved_meta``. Raises :class:`StoreError` on corruption
        or version mismatch."""
        payload = load_store(path, DB_SCHEMA, DB_VERSION)
        try:
            db = cls(novelty_threshold=payload.get("novelty_threshold",
                                                   0.995))
            cands = [candidate_from_dict(o) for o in payload["records"]]
            embs = payload.get("embeddings", [])
        except (KeyError, TypeError, ValueError) as e:
            raise StoreError(f"{path}: malformed candidate record: {e}") \
                from e
        for i, cand in enumerate(cands):
            db.records.append(cand)
            if i < len(embs):
                db.embeddings.append(np.asarray(embs[i], np.float32))
            else:
                db.embeddings.append(embed_code(cand.directive.render()))
            db._seen.add(directive_key(cand.directive))
        db.saved_meta = {"workload": payload.get("workload", ""),
                         "hardware": payload.get("hardware", "")}
        return db
