"""Candidate database with code embeddings, kNN retrieval and novelty
filtering (paper Appendix E/J).

The paper embeds candidate CUDA source with a neural code encoder; here the
"code" is the lowered program (jaxpr/StableHLO text) and the embedding is a
feature-hashed bag of op n-grams — deterministic, dependency-free, and good
enough for structural similarity (psum-heavy vs permute-heavy vs DMA-heavy
programs land far apart)."""
from __future__ import annotations

import hashlib
import re

import numpy as np

_TOKEN_RE = re.compile(r"[a-zA-Z][\w\-.]*")
DIM = 128


def embed_code(text: str, dim: int = DIM) -> np.ndarray:
    toks = _TOKEN_RE.findall(text)[:20000]
    v = np.zeros(dim, np.float32)
    for i in range(len(toks) - 1):
        g = toks[i] + " " + toks[i + 1]
        h = int(hashlib.blake2s(g.encode(), digest_size=8).hexdigest(), 16)
        v[h % dim] += 1.0 if (h >> 63) else -1.0
    n = np.linalg.norm(v)
    return v / n if n else v


class CandidateDB:
    def __init__(self, novelty_threshold: float = 0.995):
        self.records = []              # Candidate list (cid == index)
        self.embeddings = []
        self.novelty_threshold = novelty_threshold

    def add(self, cand):
        cand.cid = len(self.records)
        self.records.append(cand)
        self.embeddings.append(embed_code(cand.code_text or
                                          cand.directive.render()))
        return cand.cid

    def knn(self, cand, k=3):
        """Structurally similar prior candidates + their feedback."""
        if not self.records:
            return []
        q = embed_code(cand.code_text or cand.directive.render())
        sims = np.array([float(q @ e) for e in self.embeddings])
        order = np.argsort(-sims)
        out = []
        for i in order[:k + 1]:
            r = self.records[i]
            if r.cid == cand.cid:
                continue
            out.append((float(sims[i]), r))
        return out[:k]

    def is_novel(self, directive, code_text=""):
        """Novelty filter: reject near-identical directives already seen."""
        for r in self.records:
            if r.directive == directive:
                return False
        if code_text:
            q = embed_code(code_text)
            for e, r in zip(self.embeddings, self.records):
                if float(q @ e) > self.novelty_threshold \
                        and r.directive.as_dict() == directive.as_dict():
                    return False
        return True

    @property
    def best(self):
        done = [r for r in self.records if r.result and r.result.ok]
        return max(done, key=lambda r: r.score) if done else None

    def history(self):
        return [(r.cid, r.gen, r.island, r.mutation, r.score,
                 r.directive.behavior) for r in self.records]
