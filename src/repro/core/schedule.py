"""The collective-schedule contract — the single definition site for the
trace-time schedule machinery every device-initiated kernel builds against
(and the slow-path search refines against).

The paper's central claim is that a *structured design-space formalization*
lets an agent co-design compute and communication across many workloads.
This module is where that structure lives on the kernel side: a
:class:`CollectiveSchedule` is a trace-time total order of **rounds** —
``(edge, tile)`` events — that is identical on every rank, plus the wire /
round / tick accounting the l3 cost model charges. Three concrete builders
cover the realization matrix:

  * :class:`DispatchSchedule` — moe_dispatch permutation rounds ``(off, j)``
    over variable-size per-peer microblocks (dummy-padded for lockstep).
  * :class:`BroadcastSchedule` — gemm_allgather shift-broadcast rounds
    ``(off, t)`` (dense: nothing to pad or elide).
  * :class:`RingSchedule` — ring-rotation rounds ``(step, chunk)`` for the
    ring workloads (ring_attention KV rotation, kv_shuttle K→V tiles).

**The contract** (enforced at runtime by the legacy 0.4.x pallas
interpreter's lockstep discharge, property-tested in
``tests/test_schedules.py``):

1. ``rounds`` is a total, deterministic, rank-independent order; every
   ``(edge, tile)`` event appears exactly once. Every rank issues every
   round's DMA **unconditionally** (no role-predicated ``pl.when`` around
   ``dma.start()``) and each round's edges form a permutation.
2. ``send_window_depths(contexts)`` mirrors the kernels' bounded-issue
   algorithm: at most ``contexts`` rounds' send semaphores stay unawaited;
   the oldest is ``wait_send``-ed before the next round issues.
3. ``issued_rounds()`` / ``completion_ticks()`` are the DMA-issue and
   receive-readiness counts the cost model charges ``TILE_SYNC`` per event.
4. Receive-semaphore slots follow the :func:`sem_slot` convention — slot
   ``s`` counts arrivals from source ``s`` under either semaphore engine.
5. Numeric knobs drawn from ``design_space.TUNABLES`` need not divide a
   given shape: consumers repair them with :func:`sanitize_tile` (largest
   divisor) at their own boundary so a slow-path diff patch can never
   crash the evaluator.

This module is pure trace-time Python (no jax imports at module scope) so
the schedules stay property-testable without a device backend.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "CollectiveSchedule", "DispatchSchedule", "BroadcastSchedule",
    "RingSchedule", "SendWindow", "make_schedule",
    "make_broadcast_schedule", "make_ring_schedule", "block_counts",
    "send_window_depths", "sanitize_tile", "sanitize_combine_tile",
    "sanitize_tile_m", "sanitize_kv_chunk", "sem_slot", "check_live",
    "respill_counts",
]


# ------------------------------------------------------------ shared pieces


def send_window_depths(rounds, contexts):
    """In-flight send depth after each issued round under a ``contexts``-
    deep window — the kernels' issue algorithm (wait_send the oldest
    in-flight round before issuing past the cap) mirrored at trace time.
    Shared by every :class:`CollectiveSchedule` and property-tested in
    tests/test_schedules.py."""
    cap = max(1, int(contexts))
    depth, out = 0, []
    for _ in rounds:
        if depth >= cap:
            depth -= 1
        depth += 1
        out.append(depth)
    return out


class SendWindow:
    """The kernels' bounded-issue algorithm — the executable counterpart of
    :func:`send_window_depths` (one code path for all four kernels, so the
    property-tested trace-time mirror and the issued DMAs cannot drift).

    At most ``contexts`` *rounds'* send semaphores stay unawaited; the
    oldest round is waited before the next one issues. A round may span
    several DMA descriptors (a K/V chunk pair, a data+scale pair): they
    count as ONE window entry — :meth:`push` opens a round and
    :meth:`amend` adds a descriptor issued later in the same round.

    ``start``/``wait`` hooks customize how an entry's descriptors are
    started and retired (moe_dispatch predicates both under the same
    ``pl.when`` for dummy elision); the defaults start every descriptor
    and ``wait_send`` each on retirement.
    """

    def __init__(self, contexts, *, start=None, wait=None):
        self.cap = max(1, int(contexts))
        self._rounds = []
        self._start = start or (lambda cps: [cp.start() for cp in cps])
        self._wait = wait or (lambda cps: [cp.wait_send() for cp in cps])

    def push(self, entry):
        """Open a new round: retire the oldest past the cap, then start.
        ``entry`` is a list of descriptors (mutable, so :meth:`amend` can
        extend it) — or any opaque value when custom hooks are given."""
        if len(self._rounds) >= self.cap:
            self._wait(self._rounds.pop(0))
        self._start(entry)
        self._rounds.append(entry)

    def amend(self, cp):
        """Start a descriptor belonging to the most recent round (e.g. the
        V half of a K/V pair issued after the V tile's GEMM)."""
        cp.start()
        self._rounds[-1].append(cp)

    def drain(self):
        """Retire every in-flight round (step/kernel boundary)."""
        while self._rounds:
            self._wait(self._rounds.pop(0))


def check_live(live_ranks, n):
    """Validate a degraded-membership set against an ``n``-rank schedule.

    Returns the canonical live tuple (sorted, deduplicated). Raises
    ``ValueError`` on an empty set or an out-of-range rank — the one
    contract every ``degrade(live_ranks)`` implementation shares, so a
    malformed membership update fails loudly at trace time instead of
    wedging a kernel at run time."""
    live = tuple(sorted({int(r) for r in live_ranks}))
    if not live:
        raise ValueError("degrade: live_ranks must be non-empty "
                         "(a collective needs at least one survivor)")
    if live[0] < 0 or live[-1] >= n:
        raise ValueError(
            f"degrade: live_ranks {live} out of range for n={n}")
    return live


def respill_counts(counts, live_ranks, capacity_factor=1.25):
    """Capacity-factor re-spill: re-route the tokens of dead experts onto
    the survivors. Token-conserving (``sum`` is preserved) and
    deterministic: spilled tokens fill the survivor with the most headroom
    below ``capacity_factor * total / len(live)`` first (ties break toward
    the lower live index); once every survivor is at capacity the overflow
    spreads uniformly. The result is the ``counts`` of the degraded
    :class:`DispatchSchedule` — a smaller instance of the same class."""
    counts = tuple(int(c) for c in counts)
    live = check_live(live_ranks, len(counts))
    total = int(sum(counts))
    new = [counts[e] for e in live]
    spilled = total - sum(new)
    if spilled > 0:
        cap = max(1, int(math.ceil(capacity_factor * total / len(live))))
        while spilled:
            i = max(range(len(new)), key=lambda j: (cap - new[j], -j))
            if cap - new[i] <= 0:
                break                    # every survivor at capacity
            give = min(spilled, cap - new[i])
            new[i] += give
            spilled -= give
        if spilled:                      # overflow beyond the factor
            q, r = divmod(spilled, len(new))
            new = [c + q + (1 if i < r else 0) for i, c in enumerate(new)]
    return tuple(new)


def sanitize_tile(tile, total):
    """Largest divisor of ``total`` that is <= the requested ``tile``.

    One sanitizer algorithm for the whole package: slow-path diff patches
    draw tile knobs from the central ``TUNABLES`` grids, which need not
    divide a given workload shape — the kernel contract requires an exact
    divisor. ``None``/0 means "the whole extent" (one tile)."""
    total = int(total)
    t = int(tile) if tile else total
    t = max(1, min(t, total))
    while total % t:
        t -= 1
    return t


# per-knob aliases: each names the shape it divides (docs/kernels.md)
def sanitize_combine_tile(combine_tile, block_tokens):
    """moe_dispatch fused-combine GEMM tile rows -> divisor of the
    ``block_tokens`` microblock."""
    return sanitize_tile(combine_tile, block_tokens)


def sanitize_tile_m(tile_m, M_l):
    """gemm_allgather GEMM tile rows -> divisor of the local slab."""
    return sanitize_tile(tile_m, M_l)


def sanitize_kv_chunk(kv_chunk, rows):
    """ring rotation chunk rows -> divisor of the local KV shard."""
    return sanitize_tile(kv_chunk, rows)


def sem_slot(me, inbound_src):
    """Receive-semaphore slot for an arrival from ``inbound_src``.

    The convention is **slot s = edge from source rank s**. Under faithful
    sender-driven RDMA (hardware, or the modern ``InterpretParams``
    simulator) the *sender's* descriptor names the slot its signal lands in
    on the receiver — the issuer's own rank (``me``). The legacy lockstep
    discharge instead increments the slot named by the *receiver's* own
    descriptor — its inbound peer for this round (``inbound_src``). Both
    reduce to the same convention once routed through here; kernels with
    per-edge semaphore arrays must use this (single-edge kernels like the
    ring, whose receive semaphores are scalar per chunk slot, need not)."""
    from repro.compat import LEGACY_INTERPRET
    return inbound_src if LEGACY_INTERPRET else me


class CollectiveSchedule:
    """Base contract: a trace-time lockstep round order plus accounting.

    Concrete schedules are frozen dataclasses exposing ``rounds`` (the
    total order of ``(edge, tile)`` events), ``rows_per_round``, and the
    issue/tick counts below; kernels iterate ``rounds`` to issue DMAs and
    the l3 cost model charges the same counts."""

    @property
    def rounds(self):
        raise NotImplementedError

    def issued_rounds(self):
        """``dma_start`` rounds each rank issues (default: every round)."""
        return len(self.rounds)

    def send_window_depths(self, contexts):
        """See module-level :func:`send_window_depths`."""
        return send_window_depths(self.rounds, contexts)

    def degrade(self, live_ranks):
        """Membership-aware degraded-mode schedule over ``live_ranks``.

        Returns a **smaller instance of the same class** under compaction
        renumbering (live rank ``r`` becomes its index in the sorted live
        tuple): rounds name shift *offsets*, never absolute ranks, so the
        compacted schedule trivially re-satisfies the whole contract —
        lockstep total order, edges-exactly-once-among-live-ranks, the
        ``contexts`` window cap — and the kernels run it unmodified on the
        surviving mesh. No round ever names a dead rank, so no DMA is
        issued to (and no semaphore wait taken on) one: bounded-wait by
        construction. ``degrade`` with every rank live returns ``self``."""
        raise NotImplementedError


# ------------------------------------------------- moe_dispatch (the flagship)


def block_counts(counts, block_tokens, tight=True):
    """Microblocks per edge into each expert. Padded mode ships the
    max-capacity block count on every edge (the XLA all-to-all shape)."""
    b = [int(math.ceil(c / block_tokens)) for c in counts]
    if not tight:
        b = [max(b)] * len(b)
    return b


@dataclass(frozen=True)
class DispatchSchedule(CollectiveSchedule):
    """Trace-time routing schedule + its wire accounting (tokens, per rank).

    ``rounds`` is the lockstep permutation-round list ``[(off, j), ...]``:
    in round ``(off, j)`` rank ``r`` exchanges microblock ``j`` with peer
    ``(r - off) % n`` (dispatch) / ``(r + off) % n`` (combine). Ranks whose
    edge has fewer than ``j + 1`` real blocks ship a dummy block into the
    receiver's trash row to keep the permutation total; real hardware
    elides them (``elide_dummy``).
    """
    n: int
    block_tokens: int
    counts: tuple          # exact tokens routed to each expert (per rank)
    blocks: tuple          # microblocks per edge into each expert
    tight: bool

    @property
    def b_max(self):
        return max(self.blocks)

    @property
    def rounds(self):
        return [(off, j) for off in range(self.n)
                for j in range(self.b_max)]

    def wire_tokens(self, rank=0):
        """Exact off-rank tokens rank ``rank`` dispatches (the l3 credit):
        tight = sum(counts) - counts[rank]; padded = C * (n - 1)."""
        if self.tight:
            return int(sum(self.counts)) - int(self.counts[rank])
        return int(max(self.counts)) * (self.n - 1)

    def executed_wire_tokens(self, rank=0):
        """Block-rounded off-rank tokens the kernel actually ships for rank
        ``rank`` (real microblocks only, dummies excluded)."""
        return sum(self.blocks[e] * self.block_tokens
                   for e in range(self.n) if e != rank)

    def dummy_wire_tokens(self, rank=0):
        """Off-rank dummy (trash-row) tokens the lockstep interpreter path
        additionally ships for rank ``rank``; elided on real hardware."""
        return sum((self.b_max - self.blocks[e]) * self.block_tokens
                   for e in range(self.n) if e != rank)

    def issued_rounds(self, elide_dummy=False):
        """Dispatch ``dma_start`` rounds each rank issues: the legacy
        interpreter's lockstep rule pads every edge to ``b_max`` rounds;
        real hardware (``elide_dummy``) issues only the real microblocks
        (rank r's edge to expert e carries ``blocks[e]``, so the dispatch
        total is identical on every rank)."""
        if elide_dummy:
            return int(sum(self.blocks))
        return self.n * self.b_max

    def combine_issued_rounds(self, rank=0, elide_dummy=False):
        """Combine ``dma_start`` rounds rank ``rank`` issues. Unlike
        dispatch this is rank-dependent: expert ``rank`` returns its own
        ``blocks[rank]`` real microblocks to each of the n sources."""
        if elide_dummy:
            return self.n * int(self.blocks[rank])
        return self.n * self.b_max

    def combine_ticks(self, combine_tile=None, rank=0, elide_dummy=False):
        """Per-tile combine writes (COUNTER ticks) of the tile-fused path:
        each issued combine round splits into ``block_tokens/combine_tile``
        sub-tile DMAs, each bumping the receive semaphore independently."""
        ct = sanitize_combine_tile(combine_tile, self.block_tokens)
        return self.combine_issued_rounds(rank, elide_dummy) \
            * (self.block_tokens // ct)

    def degrade(self, live_ranks, capacity_factor=1.25):
        """Respill the dead experts' tokens across the survivors
        (:func:`respill_counts`) and rebuild the permutation schedule at
        ``n = len(live)`` — token-conserving, same ``block_tokens``/
        ``tight`` realization."""
        live = check_live(live_ranks, self.n)
        if len(live) == self.n:
            return self
        return make_schedule(
            respill_counts(self.counts, live, capacity_factor),
            self.block_tokens, self.tight)


def make_schedule(counts, block_tokens=64, tight=True):
    counts = tuple(int(c) for c in counts)
    return DispatchSchedule(
        n=len(counts), block_tokens=block_tokens, counts=counts,
        blocks=tuple(block_counts(counts, block_tokens, tight)), tight=tight)


# ----------------------------------------------------------- gemm_allgather


@dataclass(frozen=True)
class BroadcastSchedule(CollectiveSchedule):
    """Trace-time broadcast-round schedule + wire accounting (rows/rank).

    ``rounds`` is the lockstep round list ``[(off, t), ...]``: in round
    ``(off, t)`` rank ``r`` sends rows ``[t*rows_per_round, ...)`` of its
    slab to peer ``(r + off) % n`` and receives the matching rows from
    ``(r - off) % n`` — a shift permutation (exactly one incoming copy per
    rank per round), identical on every rank. The fused schedule is
    tile-major so tile ``t``'s rounds issue before tile ``t+1`` computes;
    the DEFERRED schedule is one whole-slab round per offset. The
    broadcast is *dense* (every rank ships every tile to every peer), so
    there are no dummy rounds and nothing to elide.
    """
    n: int
    M_l: int
    tile_m: int              # sanitized: always divides M_l
    fused: bool

    @property
    def nt(self):
        return self.M_l // self.tile_m

    @property
    def rows_per_round(self):
        return self.tile_m if self.fused else self.M_l

    @property
    def rounds(self):
        if self.fused:
            return [(off, t) for t in range(self.nt)
                    for off in range(1, self.n)]
        return [(off, 0) for off in range(1, self.n)]

    def wire_rows(self, rank=0):
        """Rows each rank broadcasts off-rank (dense: identical on every
        rank, and identical for the fused and deferred schedules — the
        schedule changes *when* rows move, never how many)."""
        return (self.n - 1) * self.M_l

    def completion_ticks(self, counter=True):
        """Receive-side readiness ticks: COUNTER consumes arrivals one
        tile at a time (one tick per inbound ``(src, tile)`` edge); SIGNAL
        and the DEFERRED slab path wait once per inbound edge."""
        if self.fused and counter:
            return (self.n - 1) * self.nt
        return self.n - 1

    def degrade(self, live_ranks):
        """Splice the dead ranks out of the shift permutation: offsets run
        ``1..len(live)-1`` over the compacted rank space — same slab, same
        tile realization, fewer broadcast targets."""
        live = check_live(live_ranks, self.n)
        if len(live) == self.n:
            return self
        return make_broadcast_schedule(len(live), self.M_l, self.tile_m,
                                       self.fused)


def make_broadcast_schedule(n_dev, M_l, tile_m=128, fused=True):
    return BroadcastSchedule(n=int(n_dev), M_l=int(M_l),
                             tile_m=sanitize_tile_m(tile_m, M_l),
                             fused=bool(fused))


# ------------------------------------------------- ring workloads (rotation)


@dataclass(frozen=True)
class RingSchedule(CollectiveSchedule):
    """Trace-time ring-rotation schedule (ring_attention KV rotation and
    the kv_shuttle prefill→decode tile chain).

    ``rounds`` is the lockstep round list ``[(step, c), ...]``: in rotation
    step ``step`` every rank ships the shard it currently holds one hop
    around the ring (rank ``r`` → ``(r + 1) % n`` — a shift permutation),
    split into ``nc`` chunks of ``kv_chunk`` rows. The fused schedule is
    chunk-major *within* a step: chunk ``c``'s send issues before chunk
    ``c + 1``'s compute, and the receiver ticks arrivals off one chunk at
    a time (consume chunk ``c`` of step ``s-1`` while chunk ``c+1`` is
    still in flight — the FLUX point for rings). The DEFERRED schedule is
    one whole-shard round per step. One round moves ``rows_per_round``
    rows of **each** rotated tensor (K and V ship as a pair).

    ``n = 2`` with a single step is the kv_shuttle degenerate ring: the
    prefill rank's K/V tiles chain to the decode rank chunk by chunk.
    """
    n: int
    rows: int                # KV rows per shard (local sequence length)
    kv_chunk: int            # sanitized: always divides rows
    fused: bool

    @property
    def nc(self):
        return self.rows // self.kv_chunk

    @property
    def steps(self):
        return max(0, self.n - 1)

    @property
    def rows_per_round(self):
        return self.kv_chunk if self.fused else self.rows

    @property
    def rounds(self):
        if self.fused:
            return [(step, c) for step in range(self.steps)
                    for c in range(self.nc)]
        return [(step, 0) for step in range(self.steps)]

    def wire_rows(self, rank=0):
        """Rows of each rotated tensor every rank ships off-rank: the ring
        is dense and symmetric — ``(n-1) * rows`` regardless of chunking
        (the schedule changes *when* rows move, never how many)."""
        return self.steps * self.rows

    def completion_ticks(self, counter=True):
        """Receive-side readiness ticks. The chunk-rotating (fused)
        kernels wait per-chunk semaphores regardless of completion —
        COUNTER interleaves the ticks with the chunk compute while SIGNAL
        drains a step's chunks up front, but the executed wait count is
        identical (one per ``(step, chunk)`` event), so the model charges
        both the same (no spurious SIGNAL-dominates-FLUX artifact). The
        whole-shard DEFERRED/PIPELINED path waits once per rotation step."""
        del counter
        if self.fused:
            return self.steps * self.nc
        return self.steps

    def send_window_depths(self, contexts):
        """The ring kernels drain the send window at every step boundary
        (the slot-reuse credit handshake needs a step's sends retired
        before the consumer ACKs upstream), so the in-flight depth resets
        per step — the base mirror, which windows the whole round list,
        would overstate the carried depth for rings. Every step has the
        same round count, so one step's depth profile repeats."""
        per_step = send_window_depths(range(self.nc if self.fused else 1),
                                      contexts)
        return per_step * self.steps

    def degrade(self, live_ranks):
        """Splice the dead ranks out of the rotation: the ring closes over
        the compacted live order (``len(live) - 1`` shift steps) — same
        shard rows, same chunking, fewer rotation hops."""
        live = check_live(live_ranks, self.n)
        if len(live) == self.n:
            return self
        return make_ring_schedule(len(live), self.rows, self.kv_chunk,
                                  self.fused)


def make_ring_schedule(n_dev, rows, kv_chunk=None, fused=True):
    return RingSchedule(n=int(n_dev), rows=int(rows),
                        kv_chunk=sanitize_kv_chunk(kv_chunk, rows),
                        fused=bool(fused))
