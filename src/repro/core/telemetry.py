"""Structured search/cascade/serving telemetry — the second half of the
observability layer (core/trace.py renders modeled time; this module
records *decisions*: what every candidate scored, why, and how long each
cascade level took).

* :func:`wallclock_us` — the one compile-warm-then-timed-loop wall-clock
  helper (``benchmarks/common.py`` and ``CascadeEvaluator`` both
  previously inlined it).
* :class:`EvalRecord` — one structured row per evaluated candidate: level
  reached, per-level wall timings, retries, quarantine, fault penalty,
  resolved kernel knobs. Captured inside ``CascadeEvaluator`` for every
  path — success, l1/l2 failure, evaluator error, and timeout quarantine
  — and JSON round-trippable (non-finite floats map to ``null``).
* :class:`SearchTelemetry` — aggregates the records of one ``slow_path``
  run into per-generation / per-island series (best & mean score, archive
  coverage, quarantine and retry counts, mutation-operator win rates) and
  emits the checked-in ``BENCH_search.json`` artifact (ROADMAP open item:
  track the perf story PR-over-PR). The payload keeps only
  run-deterministic fields — wall-clock timings stay out so regenerating
  the artifact on any machine is diff-stable.
* :class:`MetricsRegistry` — counters / gauges / histograms with a JSON
  snapshot, wired through ``serve/engine.py`` (step-latency histogram,
  tokens/step, watchdog incidents) and
  ``train/fault_tolerance.py::ElasticController`` (straggler incidents,
  degrade events, live-rank gauge).

Pure Python except :func:`wallclock_us` (imports jax lazily), mirroring
core/schedule.py.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field, replace

__all__ = [
    "wallclock_us", "EvalRecord", "SearchTelemetry", "MetricsRegistry",
]


def wallclock_us(fn, inputs, iters=3):
    """Small-shape wall-clock of ``fn(*inputs)`` in microseconds: one
    compile-and-warm call, then the mean of ``iters`` timed iterations."""
    import jax
    fn(*inputs)                                     # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*inputs))
    return (time.perf_counter() - t0) / iters * 1e6


def _jsonable(x):
    """None-preserving float for JSON: non-finite -> None (exact
    round-trip; JSON has no inf/nan)."""
    if x is None:
        return None
    x = float(x)
    return x if math.isfinite(x) else None


@dataclass
class EvalRecord:
    """One candidate's structured evaluation row (see module docstring).

    ``levels_s`` maps cascade level name ("l0", "l1", "l2", "l3",
    "wallclock") to the wall seconds that level took; ``t_model_ms``/
    ``t_wall_ms`` are ``None`` (not inf) when the level was never
    reached, so the record round-trips JSON exactly.  ``rejection`` is
    the deterministic rejection class ("" on success, "invalid",
    "l0:<checker code>", "l1:build", "l2:execute"/"l2:nonfinite"/
    "l2:mismatch", "quarantine", "error"); ``stage`` is the cascade
    level that was in flight when the record was cut (timing-dependent
    for quarantines, so it is excluded from the parity projection)."""
    cid: int = -1
    gen: int = 0
    island: int = 0
    mutation: str = "seed"
    directive: str = ""
    level: int = 0
    score: float = 0.0
    t_model_ms: float | None = None
    t_wall_ms: float | None = None
    levels_s: dict = field(default_factory=dict)
    retries: int = 0
    quarantined: bool = False
    fault_penalty_ms: float = 0.0
    knobs: dict = field(default_factory=dict)
    diagnostic: str = ""
    elapsed_s: float = 0.0
    rejection: str = ""
    stage: str = ""

    def to_dict(self):
        return {
            "cid": int(self.cid), "gen": int(self.gen),
            "island": int(self.island), "mutation": str(self.mutation),
            "directive": str(self.directive), "level": int(self.level),
            "score": float(self.score),
            "t_model_ms": _jsonable(self.t_model_ms),
            "t_wall_ms": _jsonable(self.t_wall_ms),
            "levels_s": {k: float(v) for k, v in self.levels_s.items()},
            "retries": int(self.retries),
            "quarantined": bool(self.quarantined),
            "fault_penalty_ms": float(self.fault_penalty_ms),
            "knobs": dict(self.knobs),
            "diagnostic": str(self.diagnostic),
            "elapsed_s": float(self.elapsed_s),
            "rejection": str(self.rejection),
            "stage": str(self.stage),
        }

    @classmethod
    def from_dict(cls, d):
        return cls(**d)

    def to_json(self):
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s):
        return cls.from_dict(json.loads(s))

    def deterministic_dict(self):
        """The run-deterministic projection of the row: everything except
        the wall-clock fields (``levels_s``, ``elapsed_s``, ``t_wall_ms``)
        and ``stage`` (the level in flight when a deadline fired is
        timing-dependent; the ``rejection`` class is not and stays).
        This is the batched-vs-sequential parity comparison key
        (docs/search.md): two evaluations of the same candidate must agree
        on this dict bit for bit; only how long the wall waited may
        differ."""
        d = self.to_dict()
        for k in ("levels_s", "elapsed_s", "t_wall_ms", "stage"):
            d.pop(k)
        return d


# ------------------------------------------------------------ search series


class SearchTelemetry:
    """Aggregates one search run's :class:`EvalRecord` stream.

    ``observe`` ingests records in evaluation order (the win-rate
    accounting is order-sensitive: a record *wins* when it strictly beats
    the best score seen before it); ``note_coverage`` stamps the archive
    coverage after a generation closes."""

    def __init__(self, workload=""):
        self.workload = str(workload)
        self.records = []
        self.coverage = {}           # gen -> archive cells occupied
        self._best = 0.0
        self._wins = {}              # mutation form -> win count
        # warm-start / transfer counters (docs/search.md). Deliberately
        # batch-invariant: the batched and sequential evaluators produce
        # byte-identical payloads, so batching stats stay OUT of here.
        self.scale = {"warm_start": False, "cache_hits": 0,
                      "transferred_seeds": 0}

    def note_scale(self, **kw):
        """Stamp warm-start/transfer counters onto the run (slow_path)."""
        for k, v in kw.items():
            self.scale[k] = v

    def observe(self, record: EvalRecord):
        self.records.append(record)
        if record.score > self._best:
            self._best = record.score
            self._wins[record.mutation] = \
                self._wins.get(record.mutation, 0) + 1

    def note_coverage(self, gen, coverage):
        self.coverage[int(gen)] = float(coverage)

    # ------------------------------------------------------------- series
    def generation_series(self):
        gens = sorted({r.gen for r in self.records})
        out = []
        for g in gens:
            rs = [r for r in self.records if r.gen == g]
            scored = [r.score for r in rs]
            out.append({
                "gen": g,
                "evals": len(rs),
                "best_score": max(scored),
                "mean_score": sum(scored) / len(scored),
                "ok": sum(1 for r in rs if r.level >= 3),
                "quarantined": sum(1 for r in rs if r.quarantined),
                "retries": sum(r.retries for r in rs),
                "archive_coverage": self.coverage.get(g),
            })
        return out

    def island_series(self):
        isls = sorted({r.island for r in self.records})
        out = []
        for i in isls:
            rs = [r for r in self.records if r.island == i]
            out.append({
                "island": i,
                "evals": len(rs),
                "best_score": max(r.score for r in rs),
                "mean_score": sum(r.score for r in rs) / len(rs),
                "quarantined": sum(1 for r in rs if r.quarantined),
            })
        return out

    def mutation_stats(self):
        """Per-mutation-operator attempt/success/win table. A *win* is a
        new global best at observe time — the cross-strategy signal the
        meta-summarizer coordinates on."""
        forms = sorted({r.mutation for r in self.records})
        out = []
        for f in forms:
            rs = [r for r in self.records if r.mutation == f]
            out.append({
                "mutation": f,
                "attempts": len(rs),
                "ok": sum(1 for r in rs if r.level >= 3),
                "wins": self._wins.get(f, 0),
                "win_rate": self._wins.get(f, 0) / len(rs),
            })
        return out

    # ------------------------------------------------------------ artifact
    def payload(self, meta=None):
        """The ``BENCH_search.json`` payload: deterministic aggregates
        only (wall-clock fields excluded — regenerating on any machine
        must be diff-stable for a checked-in artifact)."""
        best = max(self.records, key=lambda r: r.score, default=None)
        return {
            "schema": "bench-search/v2",
            "workload": self.workload,
            "meta": dict(meta or {}),
            "scale": {"warm_start": bool(self.scale["warm_start"]),
                      "cache_hits": int(self.scale["cache_hits"]),
                      "transferred_seeds":
                          int(self.scale["transferred_seeds"])},
            "totals": {
                "evals": len(self.records),
                "ok": sum(1 for r in self.records if r.level >= 3),
                "quarantined": sum(1 for r in self.records if r.quarantined),
                "retries": sum(r.retries for r in self.records),
                "best_score": self._best,
            },
            "best": None if best is None else {
                "cid": best.cid, "gen": best.gen, "island": best.island,
                "mutation": best.mutation, "directive": best.directive,
                "score": best.score, "t_model_ms": _jsonable(best.t_model_ms),
                "knobs": dict(best.knobs),
            },
            "generations": self.generation_series(),
            "islands": self.island_series(),
            "mutations": self.mutation_stats(),
        }

    def write(self, path, meta=None):
        with open(path, "w") as f:
            json.dump(self.payload(meta), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def from_candidates(cls, candidates, workload="", coverage=None):
        """Build telemetry from evaluated ``Candidate``s (the slow-path
        aggregation seam): candidates whose results carry an attached
        :class:`EvalRecord` contribute it; results from a custom evaluator
        without records are synthesized from the candidate itself."""
        tel = cls(workload)
        for c in candidates:
            rec = getattr(c.result, "record", None) if c.result else None
            if rec is None:
                res = c.result
                rec = EvalRecord(
                    cid=c.cid, gen=c.gen, island=c.island,
                    mutation=c.mutation, directive=repr(c.directive),
                    level=res.level if res else 0,
                    score=res.score if res else 0.0,
                    t_model_ms=_jsonable(res.t_model_ms) if res else None,
                    t_wall_ms=_jsonable(res.t_wall_ms) if res else None,
                    retries=res.retries if res else 0,
                    quarantined=bool(res and res.quarantined),
                    diagnostic=res.diagnostic if res else "never evaluated")
            else:
                rec = replace(rec)          # observe order owns win stats
            tel.observe(rec)
        for g, cov in (coverage or {}).items():
            tel.note_coverage(g, cov)
        return tel


# ----------------------------------------------------------------- metrics


class _Counter:
    def __init__(self):
        self.value = 0.0

    def inc(self, v=1.0):
        self.value += v


class _Gauge:
    def __init__(self):
        self.value = None

    def set(self, v):
        self.value = float(v)


class _Histogram:
    """Stores observations and reports count/sum/mean and interpolated
    quantiles — small-cardinality serving metrics, not a streaming
    sketch. ``max_samples`` bounds memory via reservoir-free decimation
    (keep every other sample once full; fine for step-latency series)."""

    def __init__(self, max_samples=4096):
        self.samples = []
        self.count = 0
        self.total = 0.0
        self.max_samples = int(max_samples)

    def observe(self, v):
        v = float(v)
        self.count += 1
        self.total += v
        self.samples.append(v)
        if len(self.samples) > self.max_samples:
            self.samples = self.samples[::2]

    def quantile(self, q):
        if not self.samples:
            return None
        s = sorted(self.samples)
        if len(s) == 1:
            return s[0]
        pos = (len(s) - 1) * min(1.0, max(0.0, float(q)))
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        frac = pos - lo
        return s[lo] * (1 - frac) + s[hi] * frac

    def summary(self):
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count if self.count else None,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "max": max(self.samples) if self.samples else None,
        }


class MetricsRegistry:
    """Minimal counter/gauge/histogram registry with a JSON snapshot.

    Instruments fetch-or-create by name (``registry.counter("tokens")``),
    so call sites never pre-declare; ``snapshot()`` is a plain dict ready
    for ``json.dump``."""

    def __init__(self):
        self._counters = {}
        self._gauges = {}
        self._histograms = {}

    def counter(self, name) -> _Counter:
        return self._counters.setdefault(str(name), _Counter())

    def gauge(self, name) -> _Gauge:
        return self._gauges.setdefault(str(name), _Gauge())

    def histogram(self, name, max_samples=4096) -> _Histogram:
        return self._histograms.setdefault(str(name),
                                           _Histogram(max_samples))

    def snapshot(self):
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }

    def to_json(self, indent=None):
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def write(self, path, indent=2):
        with open(path, "w") as f:
            f.write(self.to_json(indent=indent))
            f.write("\n")
