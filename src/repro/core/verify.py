"""Static schedule sanitizer — the cascade's l0 level.

A symbolic per-rank executor over a :class:`CollectiveSchedule`'s round
order that proves the schedule contract *without running a kernel*:

* **deadlock freedom** — every semaphore wait has a matching signal under
  the lockstep rule, and no DMA issue is role-predicated (the
  ``repro/compat.py`` rule: the legacy 0.4.x lockstep interpreter cannot
  discharge a ``pl.when``-guarded ``dma.start``);
* **slot-reuse races** — a ``sem_slot`` / VMEM double-buffer slot is never
  overwritten before its arrival tick is consumed, for every ``contexts``
  depth in ``TUNABLES``;
* **window-cap and drain invariants** — the in-flight send depth never
  exceeds ``contexts`` and the window drains where the kernel assumes;
* **conservation** — tight-wire token/row accounting balances per edge,
  including ``degrade(live_ranks)`` respills and splices (no DMA names a
  dead rank).

The pipeline is ``lower_schedule`` (schedule + kernel knobs -> a
:class:`Program` of per-rank :class:`Op` lists that mirrors what the four
kernels actually issue) then ``verify_program`` (static scans + a
vector-clock lockstep execution).  ``verify_directive`` is the cascade's
l0 entry point; ``mutation_corpus`` seeds the known bug classes that
prove the checker finds real bugs.

Modeling notes (one deliberate simplification each):

* Semaphore ticks are counted in **payload rows**, not elements — the
  kernels' element counts are ``rows * row_elems`` with a fixed row
  width per semaphore family, so the accounting is isomorphic and the
  tile-split combine balances exactly.
* A K/V chunk pair (and a data+scale pair) folds into one descriptor per
  round entry where the kernel `amend`s the window — the window depth
  and the signal counts are what the contract constrains.
* Delivery is in-order per ``(src, dst, semaphore)`` — the lockstep
  interpreter's semantics, and the strongest assumption any of the four
  kernels makes (real-block-before-dummy consumption in moe_dispatch's
  pipelined wait depends on it).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from repro.core.design_space import TUNABLES
from repro.core.schedule import (BroadcastSchedule, CollectiveSchedule,
                                 DispatchSchedule, RingSchedule, check_live,
                                 sanitize_combine_tile)

# ------------------------------------------------------------ checker catalog

# code -> one-line description; docs/static-analysis.md renders this table
# and tools/schedule_lint.py prints it under --catalog
CHECKS = {
    "role-predicated-dma": "a DMA issue is predicated on rank role — the "
        "legacy lockstep interpreter cannot discharge it (compat.py rule)",
    "lockstep-order": "round order is not the lockstep total order: "
        "non-monotone round issue on a rank, or a round's send/receive "
        "multiset is not a balanced permutation over the live ranks",
    "dead-rank-dma": "a DMA names a rank outside the live set (degrade "
        "splice violation: unbounded wait on real hardware)",
    "conservation": "tight-wire token/row accounting does not balance per "
        "edge (includes non-conserving degrade(live_ranks) respills)",
    "deadlock": "a semaphore wait can never be satisfied — the lockstep "
        "execution stalls with no matching signal in flight",
    "unmatched-signal": "a semaphore signal is never consumed (leftover "
        "arrival ticks at program end)",
    "slot-reuse": "a receive slot is overwritten before its previous "
        "occupant's arrival tick and reads are provably consumed",
    "stale-read": "a buffer read is not ordered after the write that "
        "produced the data it consumes (short/off-by-one tick)",
    "window-overflow": "in-flight send depth exceeds the contexts cap "
        "(send_window_depths contract)",
    "missing-drain": "send-window entries left in flight where the kernel "
        "assumes a drain (step/phase boundary)",
}

MUTATION_CLASSES = (
    "dropped_signal", "premature_slot_reuse", "window_overflow",
    "dead_rank_dma", "non_conserving_respill", "role_predicated",
    "reordered_round", "off_by_one_tick",
)

# mutation class -> the checker code that must flag it (class-specific
# diagnostics: each seeded bug is caught by its own check, not a generic
# failure downstream)
EXPECTED_CODE = {
    "dropped_signal": "deadlock",
    "premature_slot_reuse": "slot-reuse",
    "window_overflow": "window-overflow",
    "dead_rank_dma": "dead-rank-dma",
    "non_conserving_respill": "conservation",
    "role_predicated": "role-predicated-dma",
    "reordered_round": "lockstep-order",
    "off_by_one_tick": "stale-read",
}

_MAX_ERRORS = 24
_TRASH = "trash"


# ---------------------------------------------------------------- data model


@dataclass(frozen=True)
class Op:
    """One symbolic kernel action on one rank.

    ``kind``:
      * ``dma``       — start a remote copy: ``reads`` local regions, writes
                        ``writes`` regions at ``dst`` and (iff ``signals``)
                        enqueues ``rows`` arrival ticks on ``(dst, sem)``.
                        ``opens`` opens a new send-window entry; ``False``
                        amends the current one (K/V pair, data+scale pair).
      * ``wait``      — consume ``rows`` arrival ticks from ``(rank, sem)``.
      * ``wait_send`` — retire the oldest in-flight send-window entry.
      * ``write``     — local compute producing ``writes`` regions.
      * ``read``      — local compute consuming ``reads`` regions.
      * ``signal``    — bump ``(dst, sem)`` by ``rows`` with no payload
                        (the ring credit handshake).
    """
    kind: str
    phase: str = ""
    rnd: int = -1
    dst: int = -1
    sem: tuple = ()
    rows: int = 0
    writes: tuple = ()
    reads: tuple = ()
    predicate: object = None     # role predicate marker (contract violation)
    signals: bool = True         # dma only: bump the receive semaphore
    dummy: bool = False          # trash-row round (excluded from conservation)
    opens: bool = True           # dma only: opens a new window entry
    counted: bool = True         # dma only: counts toward edge conservation
    label: str = ""


@dataclass
class Program:
    """A lowered schedule: per-rank op lists plus the expected accounting."""
    n: int
    contexts: int
    ops: list                    # ops[r] = rank r's Op list, program order
    live: tuple
    edge_rows: dict              # (phase, src, dst) -> expected real rows
    subject: str = ""
    meta: dict = field(default_factory=dict)

    def clone(self):
        return Program(self.n, self.contexts, [list(r) for r in self.ops],
                       self.live, dict(self.edge_rows), self.subject,
                       dict(self.meta))


@dataclass(frozen=True)
class VerifyError:
    code: str
    rank: int
    op_index: int
    detail: str

    def __str__(self):
        where = f"rank {self.rank}" if self.rank >= 0 else "schedule"
        if self.op_index >= 0:
            where += f" op {self.op_index}"
        return f"[{self.code}] {where}: {self.detail}"


@dataclass(frozen=True)
class VerifyReport:
    ok: bool
    errors: tuple
    subject: str = ""
    checked: dict = field(default_factory=dict)

    def codes(self):
        return tuple(dict.fromkeys(e.code for e in self.errors))

    def summary(self, limit=3):
        if self.ok:
            return f"ok ({self.subject})" if self.subject else "ok"
        head = "; ".join(str(e) for e in self.errors[:limit])
        more = len(self.errors) - limit
        if more > 0:
            head += f" (+{more} more)"
        return head

    @staticmethod
    def merge(reports, subject=""):
        errs, checked, seen = [], {}, set()
        for r in reports:
            for e in r.errors:
                key = (e.code, e.rank, e.op_index, e.detail)
                if key not in seen:
                    seen.add(key)
                    errs.append(e)
            for k, v in r.checked.items():
                checked[k] = checked.get(k, 0) + v
        return VerifyReport(ok=not errs, errors=tuple(errs),
                            subject=subject, checked=checked)


# ------------------------------------------------------- lowering: the mirror


class _Builder:
    """Per-rank op emission with a ``SendWindow`` depth mirror: ``push_dma``
    retires the oldest entry before issuing past the ``contexts`` cap —
    byte-for-byte the kernels' bounded-issue algorithm."""

    def __init__(self, n, contexts):
        self.n = n
        self.contexts = max(1, int(contexts))
        self.ops = [[] for _ in range(n)]
        self._depth = [0] * n

    def emit(self, r, op):
        self.ops[r].append(op)

    def push_dma(self, r, **kw):
        if self._depth[r] >= self.contexts:
            self.emit(r, Op("wait_send"))
            self._depth[r] -= 1
        self.emit(r, Op("dma", opens=True, **kw))
        self._depth[r] += 1

    def amend_dma(self, r, **kw):
        self.emit(r, Op("dma", opens=False, **kw))

    def drain(self, r):
        while self._depth[r]:
            self.emit(r, Op("wait_send"))
            self._depth[r] -= 1

    def wait(self, r, sem, rows):
        if rows > 0:
            self.emit(r, Op("wait", sem=sem, rows=int(rows)))

    def program(self, edge_rows, subject, **meta):
        return Program(self.n, self.contexts, self.ops,
                       tuple(range(self.n)), edge_rows, subject, meta)


def lower_dispatch(sched, contexts, *, wire_i8=False, tile_fused=False,
                   barrier=False, pipelined=True, combine_tile=None):
    """Mirror of ``kernels/moe_dispatch.py::_moe_kernel``: staged sends,
    the full lockstep dispatch round list (dummies to the trash row), the
    three wait realizations (barrier rendezvous / pipelined real-block
    waits + dummy residue / tile-fused per-microblock combine), and the
    reverse combine permutation."""
    n, B, b_max = sched.n, sched.block_tokens, sched.b_max
    blocks = sched.blocks
    ct = sanitize_combine_tile(combine_tile, B)
    nt = B // ct
    bld = _Builder(n, contexts)
    P1, P2 = "dispatch", "combine"

    # stage every real microblock into the send queue (+ its scale row)
    for r in range(n):
        for e in range(n):
            for j in range(blocks[e]):
                bld.emit(r, Op("write", writes=(("send", e, j),)))
                if wire_i8:
                    bld.emit(r, Op("write", writes=(("sends", e, j),)))

    # lockstep dispatch rounds: rank r -> expert (r - off) % n
    for ri, (off, j) in enumerate(sched.rounds):
        for r in range(n):
            e = (r - off) % n
            real = j < blocks[e]
            bld.push_dma(
                r, phase=P1, rnd=ri, dst=e, sem=("disp", r), rows=B,
                writes=(("recv", r, j),) if real else ((_TRASH,),),
                reads=(("send", e, j),) if real else (),
                dummy=not real)
            if wire_i8:
                bld.amend_dma(
                    r, phase=P1, rnd=ri, dst=e, sem=("scale", r), rows=B,
                    writes=(("recvs", r, j),) if real else ((_TRASH,),),
                    reads=(("sends", e, j),) if real else (),
                    dummy=not real, counted=False)
    for r in range(n):
        bld.drain(r)

    def _wait_edge(r, src, nblk):
        bld.wait(r, ("disp", src), nblk * B)
        if wire_i8:
            bld.wait(r, ("scale", src), nblk * B)

    def _ffn(r, src, jlo, jhi, t=None):
        keys = tuple(("recv", src, j) for j in range(jlo, jhi))
        if wire_i8:
            keys += tuple(("recvs", src, j) for j in range(jlo, jhi))
        if keys:
            bld.emit(r, Op("read", reads=keys))
        ts = range(nt) if t is None else (t,)
        for j in range(jlo, jhi):
            for tt in ts:
                bld.emit(r, Op("write", writes=(("ffn", src, j, tt),)))

    if tile_fused:
        # per-microblock arrival waits interleaved with the sub-tile
        # combine pushes (the FLUX point) — one shared combine window
        for r in range(n):
            my = blocks[r]
            for off in range(n):
                src = (r + off) % n       # dispatch source == combine dst
                for j in range(b_max):
                    real = j < my
                    _wait_edge(r, src, 1)
                    if real:
                        _ffn(r, src, j, j + 1)
                    ri = off * b_max + j
                    for t in range(nt):
                        bld.push_dma(
                            r, phase=P2, rnd=ri, dst=src,
                            sem=("comb", r), rows=ct,
                            writes=(("comb", r, j, t),) if real
                            else ((_TRASH,),),
                            reads=(("ffn", src, j, t),) if real else (),
                            dummy=not real)
            bld.drain(r)
    else:
        if barrier or not pipelined:
            # global rendezvous: every edge lands before any expert compute
            for r in range(n):
                for s in range(n):
                    _wait_edge(r, (r + s) % n, b_max)
                for s in range(n):
                    src = (r + s) % n
                    if blocks[r]:
                        _ffn(r, src, 0, blocks[r])
        else:
            # pipelined SIGNAL: wait only the real blocks of an edge, run
            # its FFN, then tick off the dummy residue (real microblocks
            # precede dummies in the lockstep round order, so the partial
            # wait consumes exactly the real deliveries)
            for r in range(n):
                my = blocks[r]
                for s in range(n):
                    src = (r + s) % n
                    _wait_edge(r, src, my)
                    if my:
                        _ffn(r, src, 0, my)
                    _wait_edge(r, src, b_max - my)
        # combine: expert r -> source (r + off) % n, same round list
        for ri, (off, j) in enumerate(sched.rounds):
            for r in range(n):
                q = (r + off) % n
                real = j < blocks[r]
                bld.push_dma(
                    r, phase=P2, rnd=ri, dst=q, sem=("comb", r), rows=B,
                    writes=(("comb", r, j, 0),) if real else ((_TRASH,),),
                    reads=(("ffn", q, j, 0),) if real else (),
                    dummy=not real)
        for r in range(n):
            bld.drain(r)

    # final combine waits (all variants wait the padded b_max per source
    # expert) + the output assembly reads
    for r in range(n):
        for s in range(n):
            bld.wait(r, ("comb", (r + s) % n), b_max * B)
        keys = tuple(("comb", e, j, t)
                     for e in range(n) for j in range(blocks[e])
                     for t in range(nt if tile_fused else 1))
        if keys:
            bld.emit(r, Op("read", reads=keys))

    edge_rows = {}
    for r in range(n):
        for e in range(n):
            if blocks[e]:
                edge_rows[(P1, r, e)] = blocks[e] * B
        if blocks[r]:
            for q in range(n):
                edge_rows[(P2, r, q)] = blocks[r] * B
        assert sum(v for (p, s, d), v in edge_rows.items()
                   if p == P1 and s == r and d != r) \
            == sched.executed_wire_tokens(r)
    return bld.program(edge_rows, f"dispatch(n={n}, B={B}, blocks={blocks}, "
                       f"tile_fused={tile_fused}, barrier={barrier}, "
                       f"contexts={contexts})")


def lower_broadcast(sched, contexts, *, counter=True):
    """Mirror of ``kernels/gemm_allgather.py::_ga_kernel``: tile-major
    fused rounds (COUNTER ticks trail the issue by one tile) or the
    deferred whole-slab rounds."""
    n, M_l, tm, nt = sched.n, sched.M_l, sched.tile_m, sched.nt
    bld = _Builder(n, contexts)
    PH = "bcast"

    if sched.fused:
        for t in range(nt):
            for r in range(n):
                bld.emit(r, Op("write", writes=(("slab", r, t),)))
            for off in range(1, n):
                ri = t * (n - 1) + (off - 1)
                for r in range(n):
                    bld.push_dma(
                        r, phase=PH, rnd=ri, dst=(r + off) % n,
                        sem=("bcast", r), rows=tm,
                        writes=(("slab", r, t),), reads=(("slab", r, t),))
            if counter and t > 0:
                # consume tile t-1 arrivals while tile t is in flight
                for off in range(1, n):
                    for r in range(n):
                        src = (r - off) % n
                        bld.wait(r, ("bcast", src), tm)
                        bld.emit(r, Op("read", reads=(("slab", src, t - 1),)))
        for r in range(n):
            bld.drain(r)
        for off in range(1, n):
            for r in range(n):
                src = (r - off) % n
                if counter:
                    bld.wait(r, ("bcast", src), tm)
                    bld.emit(r, Op("read", reads=(("slab", src, nt - 1),)))
                else:
                    bld.wait(r, ("bcast", src), nt * tm)
                    bld.emit(r, Op("read", reads=tuple(
                        ("slab", src, t) for t in range(nt))))
    else:
        for r in range(n):
            bld.emit(r, Op("write", writes=(("slab", r),)))
        for ri, (off, _t) in enumerate(sched.rounds):
            for r in range(n):
                bld.push_dma(r, phase=PH, rnd=ri, dst=(r + off) % n,
                             sem=("bcast", r), rows=M_l,
                             writes=(("slab", r),), reads=(("slab", r),))
        for r in range(n):
            bld.drain(r)
        for off in range(1, n):
            for r in range(n):
                src = (r - off) % n
                bld.wait(r, ("bcast", src), M_l)
                bld.emit(r, Op("read", reads=(("slab", src),)))

    edge_rows = {(PH, r, (r + off) % n): M_l
                 for r in range(n) for off in range(1, n)}
    for r in range(n):
        assert sum(v for (p, s, d), v in edge_rows.items() if s == r) \
            == sched.wire_rows(r)
    return bld.program(edge_rows, f"broadcast(n={n}, M_l={M_l}, tile_m={tm}, "
                       f"fused={sched.fused}, counter={counter}, "
                       f"contexts={contexts})")


def lower_ring(sched, contexts, *, counter=True, pipelined=True, eager=False):
    """Mirror of ``kernels/ring_attention.py::_ring_kernel`` (and the
    kv_shuttle degenerate ring): alternating VMEM slots, the per-step
    credit handshake that proves slot WAR safety, chunk-interleaved
    COUNTER ticks vs up-front SIGNAL drains, and the whole-shard
    eager/lazy fence variants."""
    n, nc, cr = sched.n, sched.nc, sched.kv_chunk
    steps = sched.steps
    bld = _Builder(n, contexts)
    PH = "ring"
    fused = sched.fused

    for r in range(n):
        if fused:
            for c in range(nc):
                bld.emit(r, Op("write", writes=(("kv", 0, c),)))
        else:
            bld.emit(r, Op("write", writes=(("kv", 0),)))

    for s in range(n):
        slot = s % 2
        rotate = s <= n - 2
        if rotate and s >= 1:
            for r in range(n):
                bld.wait(r, ("credit",), 1)
        if fused:
            if not counter and s >= 1:
                # SIGNAL drains the whole step's ticks up front
                for c in range(nc):
                    for r in range(n):
                        bld.wait(r, ("kvrecv", c), cr)
            for c in range(nc):
                if counter and s >= 1:
                    for r in range(n):
                        bld.wait(r, ("kvrecv", c), cr)
                if rotate:
                    ri = s * nc + c
                    for r in range(n):
                        bld.push_dma(
                            r, phase=PH, rnd=ri, dst=(r + 1) % n,
                            sem=("kvrecv", c), rows=cr,
                            writes=(("kv", 1 - slot, c),),
                            reads=(("kv", slot, c),))
                for r in range(n):
                    bld.emit(r, Op("read", reads=(("kv", slot, c),)))
            for r in range(n):
                bld.drain(r)
        else:
            if rotate:
                ri = s
                for r in range(n):
                    bld.push_dma(r, phase=PH, rnd=ri, dst=(r + 1) % n,
                                 sem=("kvrecv", 0), rows=sched.rows,
                                 writes=(("kv", 1 - slot),),
                                 reads=(("kv", slot),))
                if eager or not pipelined:
                    for r in range(n):
                        bld.drain(r)
                        bld.wait(r, ("kvrecv", 0), sched.rows)
            for r in range(n):
                bld.emit(r, Op("read", reads=(("kv", slot),)))
            if rotate and pipelined and not eager:
                for r in range(n):
                    bld.drain(r)
                    bld.wait(r, ("kvrecv", 0), sched.rows)
        if s <= n - 3:
            for r in range(n):
                bld.emit(r, Op("signal", dst=(r - 1) % n,
                               sem=("credit",), rows=1))

    edge_rows = {}
    if steps:
        edge_rows = {(PH, r, (r + 1) % n): steps * sched.rows
                     for r in range(n)}
        for r in range(n):
            assert edge_rows[(PH, r, (r + 1) % n)] == sched.wire_rows(r)
    return bld.program(edge_rows, f"ring(n={n}, rows={sched.rows}, "
                       f"kv_chunk={cr}, fused={fused}, counter={counter}, "
                       f"contexts={contexts})")


def lower_schedule(sched, contexts, knobs=None):
    """Type-dispatched lowering: a schedule plus the workload's
    ``kernel_knobs`` realization -> the symbolic :class:`Program` the
    matching kernel would issue."""
    k = dict(knobs or {})
    if isinstance(sched, DispatchSchedule):
        return lower_dispatch(
            sched, contexts,
            wire_i8=bool(k.get("wire_i8", False)),
            tile_fused=bool(k.get("tile_fused", False)),
            barrier=bool(k.get("barrier", False)),
            pipelined=bool(k.get("pipelined", True)),
            combine_tile=k.get("combine_tile"))
    if isinstance(sched, BroadcastSchedule):
        return lower_broadcast(sched, contexts,
                               counter=bool(k.get("counter", True)))
    if isinstance(sched, RingSchedule):
        return lower_ring(sched, contexts,
                          counter=bool(k.get("counter", True)),
                          pipelined=bool(k.get("pipelined", True)),
                          eager=bool(k.get("eager", False)))
    raise TypeError(f"no lowering for {type(sched).__name__}")


# ----------------------------------------------------- the symbolic executor


class _Write:
    __slots__ = ("clock", "consumers", "label")

    def __init__(self, clock, label):
        self.clock = clock
        self.consumers = []
        self.label = label


class _Delivery:
    __slots__ = ("rows", "clock", "writes", "signaled")

    def __init__(self, rows, clock, writes, signaled):
        self.rows = rows
        self.clock = clock
        self.writes = writes
        self.signaled = signaled


class _Region:
    __slots__ = ("writes", "open_reads")

    def __init__(self):
        self.writes = []
        self.open_reads = []       # (write-or-None, start clock, reader rank)


def _leq(a, b):
    return all(x <= y for x, y in zip(a, b))


class _Executor:
    """Vector-clock lockstep execution of a :class:`Program`.

    Round-robin, one op per rank per pass; a ``wait`` whose semaphore
    deficit cannot yet be met blocks its rank.  Happens-before is the
    standard vector-clock order: joins flow only through *fully consumed*
    semaphore deliveries, so a short (off-by-one) wait leaves the arrival
    unordered and the subsequent read is flagged stale.  WAR safety
    requires every consumption of a slot's previous occupant (arrival
    ticks, compute reads, retired outbound-DMA reads) to happen-before
    the overwriting DMA's start."""

    def __init__(self, prog):
        self.p = prog
        self.errors = []
        self.clock = [[0] * prog.n for _ in range(prog.n)]
        self.window = [[] for _ in range(prog.n)]     # entries: [dma records]
        self.pending = {}        # (rank, sem) -> list of _Delivery (FIFO)
        self.unsignaled = {}     # (rank, sem) -> rows delivered sans signal
        self.regions = {}        # (rank, key) -> _Region
        self.ops_run = 0

    def err(self, code, rank, idx, detail):
        if len(self.errors) < _MAX_ERRORS:
            self.errors.append(VerifyError(code, rank, idx, detail))

    def region(self, rank, key):
        return self.regions.setdefault((rank, key), _Region())

    def _event(self, r):
        self.clock[r][r] += 1

    def _do_write(self, r, dst, key, ec, idx, label):
        if key[0] == _TRASH:
            return None
        reg = self.region(dst, key)
        if reg.writes:
            prev = reg.writes[-1]
            if not prev.consumers:
                self.err("slot-reuse", r, idx,
                         f"{key} at rank {dst} overwritten before any "
                         f"consumption of {prev.label}")
            else:
                for c in prev.consumers:
                    if not _leq(c, ec):
                        self.err("slot-reuse", r, idx,
                                 f"{key} at rank {dst} overwritten by "
                                 f"{label} before a consumption of "
                                 f"{prev.label} is ordered first")
                        break
        for _w, _c, reader in reg.open_reads:
            self.err("slot-reuse", r, idx,
                     f"{key} at rank {dst} overwritten while an outbound "
                     f"DMA read from rank {reader} is still in flight")
            break
        w = _Write(ec, label)
        reg.writes.append(w)
        return w

    def _check_read(self, r, key, ec, idx, what):
        reg = self.region(r, key)
        if reg.writes:
            w = reg.writes[-1]
            if not _leq(w.clock, ec):
                self.err("stale-read", r, idx,
                         f"{what} of {key} is not ordered after the write "
                         f"{w.label} it consumes")
            return w
        return None

    def _exec(self, r, op, idx):
        self.ops_run += 1
        k = op.kind
        if k == "dma":
            self._event(r)
            ec = tuple(self.clock[r])
            rec_reads = []
            for key in op.reads:
                w = self._check_read(r, key, ec, idx,
                                     f"DMA source read (round {op.rnd})")
                reg = self.region(r, key)
                entry = (w, ec, r)
                reg.open_reads.append(entry)
                rec_reads.append((reg, entry))
            if op.opens:
                if len(self.window[r]) >= self.p.contexts:
                    self.err("window-overflow", r, idx,
                             f"send depth {len(self.window[r]) + 1} exceeds "
                             f"contexts={self.p.contexts} at round {op.rnd}")
                self.window[r].append([rec_reads])
            elif self.window[r]:
                self.window[r][-1].append(rec_reads)
            writes = []
            label = f"DMA round {op.rnd} from rank {r}"
            for key in op.writes:
                w = self._do_write(r, op.dst, key, ec, idx, label)
                if w is not None:
                    writes.append(w)
            d = _Delivery(op.rows, ec, writes, op.signals)
            if op.signals:
                self.pending.setdefault((op.dst, op.sem), []).append(d)
            else:
                key = (op.dst, op.sem)
                self.unsignaled[key] = self.unsignaled.get(key, 0) + op.rows
        elif k == "signal":
            self._event(r)
            ec = tuple(self.clock[r])
            self.pending.setdefault((op.dst, op.sem), []).append(
                _Delivery(op.rows, ec, [], True))
        elif k == "wait":
            self._event(r)
            need = op.rows
            q = self.pending.get((r, op.sem), [])
            joined = []
            while need and q:
                d = q[0]
                take = min(need, d.rows)
                d.rows -= take
                need -= take
                if d.rows == 0:
                    q.pop(0)
                    joined.append(d)
            # joins flow only through fully consumed deliveries; a partial
            # consumption leaves the arrival unordered (stale-read ahead)
            for d in joined:
                self.clock[r] = [max(a, b)
                                 for a, b in zip(self.clock[r], d.clock)]
            ec = tuple(self.clock[r])
            for d in joined:
                for w in d.writes:
                    w.consumers.append(ec)
        elif k == "wait_send":
            self._event(r)
            ec = tuple(self.clock[r])
            if not self.window[r]:
                self.err("window-overflow", r, idx,
                         "send-window retire with nothing in flight")
                return
            entry = self.window[r].pop(0)
            for rec_reads in entry:
                for reg, oread in rec_reads:
                    if oread in reg.open_reads:
                        reg.open_reads.remove(oread)
                    w = oread[0]
                    if w is not None:
                        w.consumers.append(ec)
        elif k == "write":
            self._event(r)
            ec = tuple(self.clock[r])
            for key in op.writes:
                self._do_write(r, r, key, ec, idx, f"compute write at {idx}")
        elif k == "read":
            self._event(r)
            ec = tuple(self.clock[r])
            for key in op.reads:
                w = self._check_read(r, key, ec, idx, "compute read")
                if w is not None:
                    w.consumers.append(ec)

    def _can_wait(self, r, op):
        have = sum(d.rows for d in self.pending.get((r, op.sem), []))
        return have >= op.rows

    def run(self):
        p = self.p
        pcs = [0] * p.n
        while True:
            progressed, alldone = False, True
            for r in range(p.n):
                if pcs[r] >= len(p.ops[r]):
                    continue
                alldone = False
                op = p.ops[r][pcs[r]]
                if op.kind == "wait" and not self._can_wait(r, op):
                    continue
                self._exec(r, op, pcs[r])
                pcs[r] += 1
                progressed = True
            if alldone:
                break
            if not progressed:
                self._deadlock(pcs)
                return
            if len(self.errors) >= _MAX_ERRORS:
                return
        self._end_state()

    def _deadlock(self, pcs):
        for r in range(self.p.n):
            if pcs[r] >= len(self.p.ops[r]):
                continue
            op = self.p.ops[r][pcs[r]]
            have = sum(d.rows for d in self.pending.get((r, op.sem), []))
            detail = (f"wait on {op.sem} stalls forever: have {have} of "
                      f"{op.rows} rows signaled")
            ghost = self.unsignaled.get((r, op.sem), 0)
            if ghost:
                detail += f" ({ghost} rows delivered without a signal)"
            self.err("deadlock", r, pcs[r], detail)

    def _end_state(self):
        for r in range(self.p.n):
            if self.window[r]:
                self.err("missing-drain", r, len(self.p.ops[r]) - 1,
                         f"{len(self.window[r])} send-window entries left "
                         f"in flight at program end")
        for (r, sem), q in sorted(self.pending.items(), key=str):
            left = sum(d.rows for d in q)
            if left:
                self.err("unmatched-signal", r, len(self.p.ops[r]) - 1,
                         f"{left} arrival rows on {sem} never consumed")


# ------------------------------------------------------------- static checks


def _static_errors(prog):
    errs = []
    live = set(prog.live)
    for r in range(prog.n):
        for idx, op in enumerate(prog.ops[r]):
            if op.kind == "dma" and op.predicate is not None:
                errs.append(VerifyError(
                    "role-predicated-dma", r, idx,
                    f"DMA issue at round {op.rnd} predicated on role "
                    f"{op.predicate!r} — the legacy lockstep interpreter "
                    f"cannot discharge it"))
            if op.kind in ("dma", "signal") and op.dst not in live:
                errs.append(VerifyError(
                    "dead-rank-dma", r, idx,
                    f"{op.kind} names rank {op.dst}, outside the live set "
                    f"{tuple(sorted(live))}"))
    # lockstep total order: per-rank monotone round issue, and every round
    # a balanced permutation (same send and receive multiplicity on every
    # live rank)
    per_round = {}
    for r in range(prog.n):
        last = {}
        for idx, op in enumerate(prog.ops[r]):
            if op.kind != "dma" or not op.opens:
                continue
            if op.rnd < last.get(op.phase, -1):
                errs.append(VerifyError(
                    "lockstep-order", r, idx,
                    f"{op.phase} round {op.rnd} issued after round "
                    f"{last[op.phase]} — not the lockstep total order"))
            last[op.phase] = max(last.get(op.phase, -1), op.rnd)
            snd, rcv = per_round.setdefault((op.phase, op.rnd), ({}, {}))
            snd[r] = snd.get(r, 0) + 1
            if op.dst in live:
                rcv[op.dst] = rcv.get(op.dst, 0) + 1
    for (phase, rnd), (snd, rcv) in sorted(per_round.items()):
        for name, m in (("send", snd), ("receive", rcv)):
            counts = {m.get(r, 0) for r in prog.live}
            if len(counts) > 1:
                errs.append(VerifyError(
                    "lockstep-order", -1, -1,
                    f"{phase} round {rnd} is not a balanced permutation: "
                    f"per-rank {name} counts differ"))
                break
    return errs


def _conservation_errors(prog):
    got = {}
    for r in range(prog.n):
        for op in prog.ops[r]:
            if op.kind == "dma" and op.counted and not op.dummy and op.phase:
                key = (op.phase, r, op.dst)
                got[key] = got.get(key, 0) + op.rows
    errs = []
    for key in sorted(set(got) | set(prog.edge_rows)):
        g, w = got.get(key, 0), prog.edge_rows.get(key, 0)
        if g != w:
            phase, src, dst = key
            errs.append(VerifyError(
                "conservation", src, -1,
                f"{phase} edge {src}->{dst} moves {g} rows, accounting "
                f"requires {w}"))
            if len(errs) >= 8:
                break
    return errs


def degrade_errors(parent, live_ranks, degraded):
    """Schedule-level degrade/splice contract: the degraded schedule must
    be a smaller same-class instance over the compacted live set, and the
    respill must conserve what the class conserves (tokens for dispatch,
    slab rows for broadcast, shard rows for rings)."""
    live = check_live(live_ranks, parent.n)
    errs = []

    def bad(detail):
        errs.append(VerifyError("conservation", -1, -1, detail))

    if type(degraded) is not type(parent):
        bad(f"degrade changed schedule class: {type(parent).__name__} -> "
            f"{type(degraded).__name__}")
        return errs
    if degraded.n != len(live):
        bad(f"degraded n={degraded.n} != {len(live)} live ranks")
    if isinstance(parent, DispatchSchedule):
        if sum(degraded.counts) != sum(parent.counts):
            bad(f"respill is not token-conserving: {sum(parent.counts)} "
                f"tokens before, {sum(degraded.counts)} after")
        if degraded.block_tokens != parent.block_tokens:
            bad("respill changed the microblock realization "
                f"(block_tokens {parent.block_tokens} -> "
                f"{degraded.block_tokens})")
    elif isinstance(parent, BroadcastSchedule):
        if degraded.M_l != parent.M_l:
            bad(f"degrade changed the local slab: M_l {parent.M_l} -> "
                f"{degraded.M_l}")
    elif isinstance(parent, RingSchedule):
        if degraded.rows != parent.rows:
            bad(f"degrade changed the KV shard: rows {parent.rows} -> "
                f"{degraded.rows}")
    return errs


# ------------------------------------------------------------ the public API


def verify_program(prog):
    """Run every check on one lowered :class:`Program`.  Static scans
    (role predication, dead ranks, lockstep order, conservation) run
    first and short-circuit the symbolic execution — a malformed program
    would only cascade noise through it."""
    errs = _static_errors(prog)
    errs += _conservation_errors(prog)
    checked = {"programs": 1,
               "ops": sum(len(r) for r in prog.ops)}
    if errs:
        return VerifyReport(False, tuple(errs[:_MAX_ERRORS]), prog.subject,
                            checked)
    ex = _Executor(prog)
    ex.run()
    checked["ops_executed"] = ex.ops_run
    return VerifyReport(not ex.errors, tuple(ex.errors), prog.subject,
                        checked)


def verify_schedule(sched, *, contexts=None, knobs=None, parent=None,
                    live=None):
    """Verify a schedule across window depths (default: the full
    ``TUNABLES['contexts']`` grid).  ``parent``/``live`` additionally
    check the degrade/splice contract against the schedule this one was
    degraded from."""
    reports = []
    if parent is not None:
        derrs = degrade_errors(parent, live, sched)
        if derrs:
            reports.append(VerifyReport(False, tuple(derrs),
                                        "degrade contract", {}))
    depths = tuple(contexts) if contexts else tuple(TUNABLES["contexts"])
    for cx in depths:
        reports.append(verify_program(lower_schedule(sched, cx, knobs)))
    return VerifyReport.merge(
        reports, subject=f"{type(sched).__name__} x contexts={depths}")


def directive_programs(workload, d):
    """The symbolic programs a directive would issue on ``workload``:
    ``[]`` when the realization has no collective schedule (XLA backends,
    the kv solo tier)."""
    fn = getattr(workload, "collective_schedule", None)
    sched = fn(d) if fn is not None else None
    if sched is None:
        return []
    knobs = workload.kernel_knobs(d)
    cx = max(1, int(knobs.get("contexts", 1)))
    name = f"{type(sched).__name__}@contexts={cx}"
    return [(name, lower_schedule(sched, cx, knobs))]


def verify_directive(workload, d):
    """The cascade's l0 entry point: verify every program the directive
    realizes at its own window depth.  ``None`` means vacuously clean —
    the directive issues no collective schedule at all."""
    progs = directive_programs(workload, d)
    if not progs:
        return None
    return VerifyReport.merge(
        [verify_program(p) for _name, p in progs],
        subject="; ".join(p.subject for _name, p in progs))


# -------------------------------------------------- seeded-mutation corpus


def apply_mutation(prog, cls, rank=0):
    """Seed one known bug class into a clean program (a fresh clone).
    Raises ``ValueError`` when the class does not apply to this program
    (or is schedule-level, like ``non_conserving_respill``)."""
    p = prog.clone()
    ops = p.ops[rank]

    def find(pred):
        for i, op in enumerate(ops):
            if pred(op):
                return i
        raise ValueError(f"mutation {cls!r} does not apply to {p.subject}")

    if cls == "dropped_signal":
        i = find(lambda o: o.kind == "dma" and o.signals and not o.dummy)
        ops[i] = dataclasses.replace(ops[i], signals=False)
    elif cls == "premature_slot_reuse":
        i = find(lambda o: o.kind == "wait" and o.sem == ("credit",))
        del ops[i]
    elif cls == "window_overflow":
        i = find(lambda o: o.kind == "wait_send")
        del ops[i]
    elif cls == "dead_rank_dma":
        i = find(lambda o: o.kind == "dma" and not o.dummy)
        ops[i] = dataclasses.replace(ops[i], dst=p.n)
    elif cls == "role_predicated":
        i = find(lambda o: o.kind == "dma")
        ops[i] = dataclasses.replace(ops[i], predicate=rank)
    elif cls == "reordered_round":
        i = find(lambda o: o.kind == "dma" and o.opens)
        j = find(lambda o: o.kind == "dma" and o.opens
                 and o.phase == ops[i].phase and o.rnd > ops[i].rnd)
        ops[i], ops[j] = ops[j], ops[i]
    elif cls == "off_by_one_tick":
        i = find(lambda o: o.kind == "wait" and o.rows > 1)
        ops[i] = dataclasses.replace(ops[i], rows=ops[i].rows - 1)
    elif cls == "non_conserving_respill":
        raise ValueError("non_conserving_respill is schedule-level — use "
                         "degrade_errors/verify_schedule(parent=, live=)")
    else:
        raise ValueError(f"unknown mutation class {cls!r}")
    p.subject = f"{p.subject} + {cls}"
    return p


def mutation_corpus():
    """One seeded instance per :data:`MUTATION_CLASSES` entry over
    representative schedules of all four kernels.  Each entry carries the
    class, the checker code expected to flag it, and a ``run`` thunk
    returning the :class:`VerifyReport` — the proof obligation is
    ``entry['expect'] in run().codes()`` with the expected code first."""
    from repro.core.schedule import (make_broadcast_schedule,
                                     make_ring_schedule, make_schedule)
    disp_sched = make_schedule((96, 64, 33, 17), 32, True)
    disp = lower_dispatch(disp_sched, 2)
    ring = lower_ring(make_ring_schedule(4, 128, 32, True), 2)
    bcast = lower_broadcast(make_broadcast_schedule(4, 256, 64, True), 2)
    host = {"dropped_signal": disp, "premature_slot_reuse": ring,
            "window_overflow": bcast, "dead_rank_dma": disp,
            "role_predicated": bcast, "reordered_round": disp,
            "off_by_one_tick": ring}
    entries = []
    for cls in MUTATION_CLASSES:
        expect = EXPECTED_CODE[cls]
        if cls == "non_conserving_respill":
            live = (0, 1, 3)
            good = disp_sched.degrade(live)
            bad = DispatchSchedule(
                n=good.n, block_tokens=good.block_tokens,
                counts=(good.counts[0] + good.block_tokens,)
                + good.counts[1:],
                blocks=good.blocks, tight=good.tight)
            entries.append({
                "cls": cls, "expect": expect,
                "subject": "degraded dispatch with a tampered respill",
                "run": (lambda b=bad, l=live, p=disp_sched:
                        verify_schedule(b, contexts=(2,), parent=p, live=l)),
            })
        else:
            mut = apply_mutation(host[cls], cls)
            entries.append({"cls": cls, "expect": expect,
                            "subject": mut.subject,
                            "run": (lambda m=mut: verify_program(m))})
    return entries
