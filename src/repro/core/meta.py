"""Meta-summarizer (paper Appendix K): every k generations, digest the recent
batch, update a persistent scratchpad of what worked / what failed, and emit
ranked recommendations injected into the mutation context — generation-over-
generation learning without touching the optimizer itself."""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.design_space import BACKENDS, DIMENSIONS, PLACEMENTS


@dataclass
class MetaSummarizer:
    every: int = 3
    scratchpad: dict = field(default_factory=lambda: {
        "tried_behaviors": {}, "dim_value_scores": {}, "fail_reasons": {}})
    recommendations: list = field(default_factory=list)
    digests: list = field(default_factory=list)

    def observe(self, cand):
        sp = self.scratchpad
        b = cand.directive.behavior
        cur = sp["tried_behaviors"].get(b, 0.0)
        sp["tried_behaviors"][b] = max(cur, cand.score)
        for dim in DIMENSIONS:
            v = getattr(cand.directive, dim)
            bucket = sp["dim_value_scores"].setdefault(dim, {}).setdefault(
                v, [0.0, 0])
            bucket[0] += cand.score
            bucket[1] += 1
        if cand.result and not cand.result.ok:
            reason = cand.result.diagnostic.split(":")[0]
            sp["fail_reasons"][reason] = sp["fail_reasons"].get(reason, 0) + 1

    def summarize(self, gen, db):
        """(i) digest, (ii) scratchpad update (continuous via observe),
        (iii) ranked recommendations for the next generation."""
        sp = self.scratchpad
        recent = [r for r in db.records if r.gen >= gen - self.every]
        ok = [r for r in recent if r.result and r.result.ok]
        digest = {
            "gen": gen,
            "evaluated": len(recent),
            "passed": len(ok),
            "best_recent": max((r.score for r in ok), default=0.0),
            "best_overall": db.best.score if db.best else 0.0,
            "behaviors_covered": len(sp["tried_behaviors"]),
        }
        self.digests.append(digest)
        recs = []
        # recommend untried promising behaviors (cross-pollination targets)
        best = db.best
        if best is not None:
            for p in PLACEMENTS:
                for b in BACKENDS:
                    key = (b, p, best.directive.completion)
                    if key not in sp["tried_behaviors"] \
                            and p != "DEFERRED":
                        recs.append({"kind": "try_behavior", "backend": b,
                                     "placement": p,
                                     "completion": best.directive.completion})
        # per-dimension winners: values with the best mean score
        for dim, vals in sp["dim_value_scores"].items():
            ranked = sorted(((s / max(1, n), v) for v, (s, n) in vals.items()),
                            reverse=True)
            if len(ranked) >= 2 and ranked[0][0] > 1.05 * ranked[1][0]:
                recs.append({"kind": "prefer", "dim": dim,
                             "value": ranked[0][1]})
        # dominant-bottleneck hint from the best candidate's diagnostics
        recs.append({"kind": "bottleneck", "which": "collective"})
        self.recommendations = recs[:8]
        return digest, self.recommendations
