"""Roofline cost model over a compiled SPMD module (TPU v5e target).

Three terms per chip:
  compute    = HLO_FLOPs / peak_bf16_flops
  memory     = HLO_bytes / hbm_bw
  collective = sum(per-op wire bytes) / link_bw   (DCN-crossing ops charged
               at dcn_bw; all-reduce counts 2(n-1)/n, gather/scatter/a2a
               (n-1)/n, permute 1x)

FLOPs / bytes come from ``compiled.cost_analysis()`` (the per-device SPMD
program). Collective payloads are parsed from the HLO text — XLA does not
report them in cost_analysis.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.core.hardware import ChipSpec, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CONVERT_RE = re.compile(r"=\s*f32\[([\d,]+)\]\S*\s+convert\(")


def conversion_overhead_bytes(hlo_text: str, min_bytes: int = 2**20) -> float:
    """CPU-backend f32-promotion overhead: XLA:CPU converts bf16 weights to
    f32 before dots (no native bf16 matmul), so cost_analysis charges an f32
    write + f32 re-read that a TPU would never issue. Sum 2x the f32 size of
    every large convert — subtracting this approximates TPU-native traffic.
    """
    total = 0.0
    for m in _CONVERT_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        b = n * 4
        if b >= min_bytes:
            total += 2.0 * b
    return total
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str):
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    payload_bytes: int            # max(result, operands) payload per device
    group_size: int
    crosses_pod: bool
    wire_bytes: float             # effective bytes on the wire per device

    def describe(self):
        where = "DCN" if self.crosses_pod else "ICI"
        return (f"{self.kind:20s} {self.payload_bytes/2**20:9.2f} MiB "
                f"group={self.group_size:4d} {where} "
                f"wire={self.wire_bytes/2**20:9.2f} MiB")


# ------------------------------------------------ auditable cost breakdown
#
# The l3 analytic models used to return one opaque float; the observability
# layer (core/trace.py) needs the *composition* — which modeled milliseconds
# are compute, wire, overlap span, window stall, sync, launch. Workloads now
# build a CostBreakdown (ordered segments whose sum IS the analytic cost)
# and derive ``analytic_cost`` from it, so the timeline rendered from the
# breakdown is equal to the scalar the cascade scores by construction — the
# cost model becomes auditable instead of a scalar.

SEGMENT_KINDS = ("compute", "wire", "overlap", "stall", "sync", "launch",
                 "quant", "recovery", "remesh", "total")


@dataclass(frozen=True)
class CostSegment:
    """One named slice of the modeled critical path. ``kind`` categorizes
    the slice for the trace renderer (``SEGMENT_KINDS``); ``meta`` carries
    free-form detail (e.g. the compute/wire terms an ``overlap`` span
    hides)."""
    name: str
    dur_s: float
    kind: str = "compute"
    meta: dict = field(default_factory=dict)


@dataclass(frozen=True)
class CostBreakdown:
    """The ordered decomposition of one directive's l3 analytic cost.

    ``total`` is the plain left-fold sum of the segments — workloads return
    it from ``analytic_cost``, and ``core/trace.py::schedule_timeline``
    lays the same segments out as trace spans, so the trace's critical-path
    sum equals ``analytic_cost()`` by construction. ``schedule`` (when the
    directive is kernelized) is the trace-time ``CollectiveSchedule`` the
    renderer draws DMA-round / send-window / arrival-tick detail tracks
    from; ``knobs`` is the ``kernel_knobs`` mapping that built it."""
    segments: tuple
    schedule: object = None       # CollectiveSchedule | None
    knobs: dict = field(default_factory=dict)
    meta: dict = field(default_factory=dict)

    @property
    def total(self) -> float:
        return sum(s.dur_s for s in self.segments)

    def segment(self, name):
        for s in self.segments:
            if s.name == name:
                return s
        raise KeyError(name)


def per_tile_exposed_s(wire_bytes, link_bw, tiles) -> float:
    """Per-tile fused-communication credit (the FLUX/CoCoNet TILE_FUSED
    point): when a transfer is issued per output tile from inside the
    compute loop, tile t's wire time hides behind the compute of tile t+1
    and only the final tile's transfer stays exposed on the critical path.
    """
    return wire_bytes / link_bw / max(1, int(tiles))


def window_stall_factor(contexts) -> float:
    """Send-window recycle stall of a ``contexts``-deep in-flight window:
    the oldest send must drain before the next round may issue, leaving
    ~``1/contexts`` of a tile's wire unhidden. Scales the per-tile exposed
    tail in every kernelized TILE_FUSED cost model (the knob the slow
    path's ``contexts`` diff patches move)."""
    return 1.0 + 1.0 / max(1, int(contexts))


def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * f
    if kind == "collective-permute" or kind == "collective-broadcast":
        return 1.0
    return f                       # all-gather, reduce-scatter, all-to-all


def parse_collectives(hlo_text: str, chips_per_pod: int = 0):
    """Extract collective ops + wire bytes from HLO text.

    Counts ``op`` and ``op-start`` forms, skips ``-done``. ``chips_per_pod``
    > 0 enables DCN detection (any replica group spanning a pod boundary).
    """
    ops = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", line)
        if not m:
            continue
        rest = m.group(1)
        found = None
        for op in COLLECTIVE_OPS:
            if re.search(rf"\b{op}(-start)?\(", rest):
                found = op
                break
        if not found:
            continue
        # result types are before the op name; operands inside parens
        head, _, tail = rest.partition(f"{found}")
        result_bytes = _shape_bytes(head)
        operand_bytes = _shape_bytes(tail.split(", replica_groups")[0]
                                     .split(", channel_id")[0])
        payload = max(result_bytes, operand_bytes)
        gsize, crosses = _parse_groups(rest, chips_per_pod)
        kind = found
        wire = payload * _wire_factor(kind, gsize)
        # The CPU backend promotes bf16 reductions to f32 ("*_promoted"
        # to_apply regions); on TPU the wire dtype stays bf16 — correct 2x.
        if "promoted" in rest:
            wire *= 0.5
        ops.append(CollectiveOp(
            kind=kind, payload_bytes=payload, group_size=gsize,
            crosses_pod=crosses, wire_bytes=wire))
    return ops


def _parse_groups(rest: str, chips_per_pod: int):
    m = _IOTA_GROUPS_RE.search(rest)
    if m:
        n_groups, gsize = int(m.group(1)), int(m.group(2))
        # iota order: consecutive ids in a group -> crosses pod iff the group
        # stride spans the pod boundary; detect via transpose suffix
        crosses = False
        if chips_per_pod and gsize > 1:
            tm = re.search(r"replica_groups=\[\d+,\d+\]<=\[([\d,]+)\]"
                           r"(T\(([\d,]+)\))?", rest)
            if tm:
                dims = [int(x) for x in tm.group(1).split(",")]
                total = 1
                for d in dims:
                    total *= d
                # a group of consecutive iota ids stays within a pod iff
                # gsize <= chips_per_pod and no transpose reorders across it
                if tm.group(2):
                    crosses = total > chips_per_pod
                else:
                    crosses = gsize > chips_per_pod
        return gsize, crosses
    m = _LIST_GROUPS_RE.search(rest)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        crosses = False
        if chips_per_pod and ids:
            crosses = (max(ids) // chips_per_pod) != (min(ids) // chips_per_pod)
        return max(1, len(ids)), crosses
    return 1, False


@dataclass
class RooflineReport:
    flops: float
    bytes_accessed: float
    collectives: list
    chip: ChipSpec = field(default_factory=lambda: V5E)
    convert_overhead: float = 0.0     # CPU f32-promotion bytes (see above)

    @property
    def compute_s(self):
        return self.flops / self.chip.peak_bf16_flops

    @property
    def memory_s(self):
        return self.bytes_accessed / self.chip.hbm_bw

    @property
    def memory_corrected_s(self):
        """Memory term minus the CPU-only f32-promotion traffic."""
        return max(0.0, self.bytes_accessed - self.convert_overhead) \
            / self.chip.hbm_bw

    @property
    def ici_wire_bytes(self):
        return sum(c.wire_bytes for c in self.collectives if not c.crosses_pod)

    @property
    def dcn_wire_bytes(self):
        return sum(c.wire_bytes for c in self.collectives if c.crosses_pod)

    @property
    def collective_s(self):
        return (self.ici_wire_bytes / self.chip.ici_link_bw
                + self.dcn_wire_bytes / self.chip.dcn_bw)

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self):
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_time_s(self):
        """No-overlap upper bound."""
        return self.compute_s + self.memory_s + self.collective_s

    def extrapolate(self, rep2, repeats: int):
        """Linear depth extrapolation: self is the R=1 module, rep2 the R=2
        module; returns the R=repeats estimate. Collectives are diffed as a
        multiset — the per-layer body collectives appear (repeats-1) extra
        times."""
        from collections import Counter

        def key(c):
            return (c.kind, c.payload_bytes, c.group_size, c.crosses_pod,
                    c.wire_bytes)

        c1 = Counter(key(c) for c in self.collectives)
        c2 = Counter(key(c) for c in rep2.collectives)
        body = c2 - c1
        colls = list(self.collectives)
        for (kind, payload, gsize, crosses, wire), cnt in body.items():
            for _ in range(cnt * (repeats - 1)):
                colls.append(CollectiveOp(kind, payload, gsize, crosses, wire))
        return RooflineReport(
            flops=self.flops + (repeats - 1) * (rep2.flops - self.flops),
            bytes_accessed=self.bytes_accessed
            + (repeats - 1) * (rep2.bytes_accessed - self.bytes_accessed),
            collectives=colls, chip=self.chip,
            convert_overhead=self.convert_overhead + (repeats - 1)
            * (rep2.convert_overhead - self.convert_overhead))

    def summary(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "ici_wire_bytes": self.ici_wire_bytes,
            "dcn_wire_bytes": self.dcn_wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_corrected_s": self.memory_corrected_s,
            "convert_overhead_bytes": self.convert_overhead,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "n_collectives": len(self.collectives),
        }


def roofline_from_compiled(compiled, chips_per_pod=0, chip: ChipSpec = V5E,
                           hlo_text=None):
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text, chips_per_pod)
    return RooflineReport(flops=flops, bytes_accessed=byts, collectives=colls,
                          chip=chip,
                          convert_overhead=conversion_overhead_bytes(text))
