"""Hardware context (paper Appendix C, adapted to TPU v5e).

The paper injects dynamically-extracted hardware context (GPU model, SM
counts, link types) into the agent prompt. Here the equivalent is a typed
``HardwareContext`` extracted from the mesh + target-chip constants, consumed
by the cost model and by the mutation operator (so search decisions reflect
the deployment, not priors).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12          # FLOP/s per chip
    hbm_bw: float = 819e9                    # B/s per chip
    ici_link_bw: float = 50e9                # B/s per ICI link (one direction)
    ici_links_per_axis: int = 2              # bidirectional ring per torus axis
    dcn_bw: float = 25e9                     # B/s per host, cross-pod
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20


V5E = ChipSpec()


@dataclass(frozen=True)
class HardwareContext:
    chip: ChipSpec
    mesh_shape: tuple                        # e.g. (2, 16, 16)
    mesh_axes: tuple                         # e.g. ("pod", "data", "model")
    chips_per_pod: int
    n_chips: int
    has_dcn: bool

    @property
    def fingerprint(self) -> str:
        """Stable identity of the deployment target — the hardware half of
        the warm-start eval-cache key (docs/search.md): a cached score is
        only reusable on the chip/mesh it was modeled for."""
        shape = "x".join(str(s) for s in self.mesh_shape)
        return (f"{self.chip.name}|mesh={shape}"
                f"|axes={','.join(self.mesh_axes)}|dcn={int(self.has_dcn)}")

    @property
    def topology_summary(self) -> str:
        axes = ", ".join(f"{a}={s}" for a, s in zip(self.mesh_axes, self.mesh_shape))
        kind = "multi-pod (ICI intra-pod + DCN cross-pod)" if self.has_dcn else \
            "single-pod (ICI torus)"
        return (f"{self.chip.name} mesh [{axes}] — {self.n_chips} chips, {kind}; "
                f"{self.chip.peak_bf16_flops/1e12:.0f} TFLOP/s bf16, "
                f"{self.chip.hbm_bw/1e9:.0f} GB/s HBM, "
                f"{self.chip.ici_link_bw/1e9:.0f} GB/s/link ICI")


def extract_hardware_context(mesh, chip: ChipSpec = V5E) -> HardwareContext:
    shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    axes = tuple(mesh.axis_names)
    has_dcn = "pod" in axes and mesh.shape["pod"] > 1
    n = 1
    for s in shape:
        n *= s
    per_pod = n // (mesh.shape["pod"] if has_dcn else 1)
    return HardwareContext(chip=chip, mesh_shape=shape, mesh_axes=axes,
                           chips_per_pod=per_pod, n_chips=n, has_dcn=has_dcn)
