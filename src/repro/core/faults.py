"""Fault model for the collective kernels — the injection/charging side of
the degraded-mode schedule layer (``core/schedule.py::degrade``).

A :class:`FaultSpec` names one failure of the deployment the search must
survive; a :class:`FaultPlan` bundles the specs of one scenario. Plans are
consumed at two cascade levels:

* **l2 (interpret)** — a dropped peer is realized *structurally*: the
  workload reshapes onto the survivors (``Workload.degrade``), the
  schedules splice/respill the dead rank out, and the degraded kernel runs
  unmodified on the surviving mesh (tests/scripts/fault_suite.py). Wire
  faults (:data:`CORRUPT_WIRE`/:data:`TRUNCATED_WIRE`) are applied to the
  kernel output via :func:`inject_wire_fault` so the evaluator's
  finite/rel-err checks must classify them. A delayed-DMA straggler has no
  l2 observable (the interpreter is lockstep-sequential by construction);
  it is charged at l3 and fed to the :class:`StragglerWatchdog` as wall
  time.
* **l3 (analytic)** — :func:`fault_cost` prices the scenario: the degraded
  round count via the degraded workload's own ``analytic_cost``, the dead
  ranks' resident state re-materialized over ICI (the recovery term that
  keeps a smaller mesh from modeling *cheaper* than the healthy one), a
  membership-rendezvous constant, and the straggler stall via
  ``window_stall_factor`` — a ``contexts``-deep send window hides all but
  ``1/contexts`` of each delayed round's blip.

:func:`survival_report` evaluates a plan set into the ``fault_report``
attached to ``EvalResult`` so the slow path can optimize a
(throughput, fault-survival) trade-off (``CascadeEvaluator(fault_weight=)``).

Pure trace-time Python except :func:`inject_wire_fault` (imports jax
lazily), mirroring core/schedule.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.cost_model import window_stall_factor

__all__ = [
    "DROPPED_PEER", "STRAGGLER", "CORRUPT_WIRE", "TRUNCATED_WIRE",
    "FAULT_KINDS", "REMESH_OVERHEAD", "FaultSpec", "FaultPlan",
    "fault_cost", "survival_report", "inject_wire_fault",
]

DROPPED_PEER = "dropped_peer"        # rank leaves the membership for good
STRAGGLER = "straggler"              # rank's DMAs land late for some rounds
CORRUPT_WIRE = "corrupt_wire"        # payload arrives, contents are garbage
TRUNCATED_WIRE = "truncated_wire"    # payload arrives short (tail missing)
FAULT_KINDS = (DROPPED_PEER, STRAGGLER, CORRUPT_WIRE, TRUNCATED_WIRE)

# control-plane rendezvous to agree on the new membership and rebuild the
# trace-time schedules (a constant: the schedules are pure Python)
REMESH_OVERHEAD = 250e-6


@dataclass(frozen=True)
class FaultSpec:
    """One injected failure. ``rank`` is the victim; ``rounds``/``delay_s``
    size a straggler (delayed rounds and per-round added latency);
    ``rows`` sizes a wire fault (corrupted leading / truncated trailing
    rows of the payload)."""
    kind: str
    rank: int = 0
    rounds: int = 0
    delay_s: float = 0.0
    rows: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")


@dataclass(frozen=True)
class FaultPlan:
    """A named failure scenario: the fault set one candidate is scored
    against. Frozen and hashable so plans can key report dicts."""
    name: str
    faults: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    @property
    def healthy(self):
        return not self.faults

    def dropped(self):
        """Ranks the plan removes from the membership, sorted."""
        return tuple(sorted({f.rank for f in self.faults
                             if f.kind == DROPPED_PEER}))

    def live_ranks(self, n):
        """Surviving membership of an ``n``-rank deployment under this
        plan (may be empty — callers validate via ``check_live``)."""
        dead = set(self.dropped())
        return tuple(r for r in range(n) if r not in dead)

    def straggler_stall_s(self, contexts):
        """Modeled stall of the plan's delayed-DMA rounds under a
        ``contexts``-deep send window: the window floats past a late
        round, leaving ``window_stall_factor(contexts) - 1 = 1/contexts``
        of each blip exposed — deeper windows absorb stragglers, which is
        exactly the trade-off the search should see."""
        exposed = window_stall_factor(max(1, int(contexts))) - 1.0
        return sum(f.rounds * f.delay_s * exposed
                   for f in self.faults if f.kind == STRAGGLER)

    def wire_faults(self):
        return tuple(f for f in self.faults
                     if f.kind in (CORRUPT_WIRE, TRUNCATED_WIRE))


def fault_cost(workload, directive, hw, plan):
    """l3 cost of ``directive`` on ``workload`` under ``plan`` (seconds).

    Dropped peers reshape the workload onto the survivors
    (``workload.degrade``) and add the recovery charge: each dead rank's
    resident state (``state_bytes_per_rank``) re-materializes over ICI,
    plus :data:`REMESH_OVERHEAD` for the membership rendezvous. Straggler
    rounds add the window-absorbed stall. Raises if the plan leaves no
    survivor — a scenario the deployment cannot degrade through."""
    n = workload.n_dev
    live = plan.live_ranks(n)
    if len(live) == n:
        t = workload.analytic_cost(directive, hw)
    else:
        from repro.core.schedule import check_live
        live = check_live(live, n)       # raises on an empty survivor set
        degraded = workload.degrade(live)
        t = degraded.analytic_cost(directive, hw)
        dead = n - len(live)
        t += dead * workload.state_bytes_per_rank() / hw.chip.ici_link_bw
        t += REMESH_OVERHEAD
    return t + plan.straggler_stall_s(directive.contexts)


def survival_report(workload, directive, hw, plans):
    """Evaluate ``plans`` into the ``EvalResult.fault_report`` dict:
    ``{plan.name: {healthy_ms, degraded_ms, survives}}``. A plan the
    workload cannot degrade through (no survivors, no degraded reshape)
    reports ``survives=False`` with a diagnostic instead of raising — the
    cascade must never die on a fault scenario."""
    healthy_ms = workload.analytic_cost(directive, hw) * 1e3
    report = {}
    for plan in plans:
        try:
            ms = fault_cost(workload, directive, hw, plan) * 1e3
            survives = math.isfinite(ms)
            entry = {"healthy_ms": healthy_ms, "degraded_ms": ms,
                     "survives": survives}
        except Exception as e:
            entry = {"healthy_ms": healthy_ms, "degraded_ms": float("inf"),
                     "survives": False,
                     "diagnostic": f"{type(e).__name__}: {e}"}
        report[plan.name] = entry
    return report


def inject_wire_fault(out, spec):
    """Apply a wire fault to a kernel output pytree (the l2 injection
    point): :data:`CORRUPT_WIRE` poisons the leading ``spec.rows`` rows of
    every floating leaf with NaN (the evaluator's finite check must flag
    it); :data:`TRUNCATED_WIRE` zeroes the trailing rows (the rel-err
    check must flag it). Non-float leaves pass through untouched."""
    import jax
    import jax.numpy as jnp

    if spec.kind not in (CORRUPT_WIRE, TRUNCATED_WIRE):
        raise ValueError(f"not a wire fault: {spec.kind!r}")

    def hit(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.floating) or leaf.ndim == 0:
            return leaf
        rows = max(1, min(int(spec.rows), leaf.shape[0]))
        if spec.kind == CORRUPT_WIRE:
            return leaf.at[:rows].set(jnp.nan)
        return leaf.at[leaf.shape[0] - rows:].set(0.0)

    return jax.tree.map(hit, out)
