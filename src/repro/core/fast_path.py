"""Fast-path agent (paper §3.2, Appendix D): correctness-first transformation
of the host-driven baseline into a verified device-initiated seed.

  1. CUDA Code Analysis -> here: jaxpr static analysis (repro.core.comm_graph)
     recovers the communication dependency graph of the host baseline.
  2. Host-to-Device Transformation, two judge-checked stages:
       Stage A (communication setup): pick the device backend for the target
         topology, instantiate the directive's resource plan (buffer slots,
         completion mechanism) and check the program *lowers* (the
         infrastructure compiles before any semantic change).
       Stage B (communication replacement): build the device-initiated
         program under the FIXED CONSERVATIVE directive and verify it
         numerically against the oracle. On failure, the judge diagnoses and
         the next legal fallback is tried (verify-and-repair loop).
  3. Evolve-Block Annotation: the verified seed is annotated with the
     mutable design-space dimensions (everything outside them is frozen so
     downstream mutations cannot break the evaluation harness).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax

from repro.core import comm_graph
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import CONSERVATIVE, Directive


@dataclass
class VerifiedSeed:
    workload: object
    directive: Directive
    candidate: Candidate
    graph: object                       # CommGraph of the host baseline
    evolve_dims: tuple
    log: list = field(default_factory=list)


DEVICE_CONSERVATIVE = dataclasses.replace(
    CONSERVATIVE, backend="PALLAS_RDMA")
# Stage B's fixed conservative directive, device-initiated flavour:
# in-kernel DEFERRED placement, BARRIER completion, WORLD scope, KERNEL
# issuer, PER_PEER granularity, RELEASE ordering, single context.


def fast_path(workload, mesh, hw, *, evaluator=None, max_iters=3,
              verbose=False):
    """Returns a VerifiedSeed. Raises RuntimeError if no conservative
    directive verifies within the iteration budget."""
    log = []
    ev = evaluator or CascadeEvaluator(workload, mesh, hw)

    # -- step 1: static analysis of the host-driven baseline ---------------
    host = workload.host_baseline(mesh)
    graph = comm_graph.analyze(host, *ev.inputs)
    log.append(f"analyzer: {len(graph.nodes)} collectives / "
               f"{graph.n_eqns} eqns; {graph.collective_bytes} payload bytes")
    if verbose:
        print(graph.describe())

    # -- step 2: staged transformation under conservative directives -------
    trial_order = [DEVICE_CONSERVATIVE, CONSERVATIVE]
    if not workload.kernelizable:
        trial_order = [CONSERVATIVE]
    last_diag = ""
    for it, d in enumerate(trial_order * max_iters):
        d = dataclasses.replace(
            d, tunables=tuple(sorted(workload.default_tunables().items())))
        viol = workload.check(d, hw)
        if viol:
            log.append(f"stage A reject {d.backend}: {viol}")
            continue
        # Stage A: infrastructure must lower (no semantic checks yet)
        try:
            fn = workload.build(d, mesh)
            jax.jit(fn).lower(*ev.inputs)
            log.append(f"stage A ok: {d.backend} infrastructure lowers")
        except Exception as e:  # judge: route the root cause to the next try
            last_diag = f"stage A failed ({d.backend}): {e}"
            log.append(last_diag)
            continue
        # Stage B: semantic replacement, verified vs the oracle
        cand = Candidate(directive=d, mutation="fast-path-seed")
        res = ev.evaluate(cand)
        cand.result = res
        if res.ok:
            log.append(f"stage B verified on iteration {it + 1}: "
                       f"score {res.score:.2f}")
            # step 3: evolve-block annotation
            seed = VerifiedSeed(workload=workload, directive=d,
                                candidate=cand, graph=graph,
                                evolve_dims=workload.evolve_dims, log=log)
            return seed
        last_diag = res.diagnostic
        log.append(f"stage B failed (judge): {last_diag}")
    raise RuntimeError("fast path could not produce a verified seed:\n"
                       + "\n".join(log))
