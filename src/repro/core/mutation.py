"""Phase-dependent variation operator — LLMMutate, Algorithm 2 (paper
Appendix I) — with the paper's three mutation forms:

  REWRITE   — large-step: resample several dimensions (architectural change)
  DIFF      — fine-grained: perturb one dimension or one numeric tunable
  CROSSOVER — synthesize from the parent + a MAP-Elites archive inspiration

The operator is *bounded*: it can only emit points of C that validate for
the workload's traits (the paper's "LLMs as bounded operators over
domain-defined search spaces"). Two implementations share the contract:

  * HeuristicMutator — deterministic, semantically informed (consumes the
    same MutationContext the paper feeds its LLM: parent + feedback, archive
    inspirations, meta-recommendations, hardware context) — used offline.
  * LLMMutator — assembles the paper's prompt (backend-conditioned API
    context, strategy knowledge, hardware context, directive syntax) and
    delegates to a user-supplied ``llm_fn``; for API-connected deployments.
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, field

from repro.core.design_space import (DIMENSIONS, Directive, is_valid,
                                     random_directive)


@dataclass
class MutationContext:
    parent: "Candidate"
    phase: str                       # "explore" | "exploit"
    archive_samples: list = field(default_factory=list)
    neighbors: list = field(default_factory=list)     # (sim, Candidate)
    recommendations: list = field(default_factory=list)
    hardware: object = None
    traits: dict = field(default_factory=dict)
    tunable_space: dict = field(default_factory=dict)  # name -> candidates


class MutationOperator:
    def propose(self, ctx: MutationContext, rng: random.Random) -> tuple:
        """Returns (directive, mutation_kind)."""
        raise NotImplementedError


# --------------------------------------------------------------- heuristic

# dimensions most likely to move the needle for a given bottleneck diagnosis
_BOTTLENECK_DIMS = {
    "collective": ("placement", "backend", "granularity", "contexts"),
    "compute": ("granularity", "issuer", "contexts"),
    "overhead": ("completion", "ordering", "scope"),
}


class HeuristicMutator(MutationOperator):
    """Semantically informed bounded operator. Explore = high-temperature
    rewrites/crossovers toward structurally different behaviors; exploit =
    low-temperature single-dimension diffs biased by feedback and
    meta-recommendations.

    ``bounded=False`` disables the design-space bounding (candidates are
    free combinations, possibly invalid) — the ablation analogue of the
    paper's "unconstrained code generation" baseline, where the cascade must
    reject broken candidates at l1.
    """

    def __init__(self, bounded: bool = True):
        self.bounded = bounded

    def propose(self, ctx, rng):
        parent = ctx.parent.directive
        traits = ctx.traits
        if ctx.phase == "explore":
            form = rng.choices(["rewrite", "crossover", "diff"],
                               weights=[0.6, 0.25, 0.15])[0]
        else:
            form = rng.choices(["diff", "crossover", "rewrite"],
                               weights=[0.7, 0.2, 0.1])[0]
        if form == "crossover" and not ctx.archive_samples:
            form = "rewrite" if ctx.phase == "explore" else "diff"

        if not self.bounded and form == "rewrite":
            d = Directive(**{k: rng.choice(v) for k, v in DIMENSIONS.items()})
            return self._retune(d, ctx, rng), "rewrite-unbounded"
        if form == "rewrite":
            d = self._rewrite(parent, ctx, rng)
        elif form == "crossover":
            d = self._crossover(parent, ctx.archive_samples, rng, traits)
        else:
            d = self._diff(parent, ctx, rng)
        if self.bounded and not is_valid(d, **traits):
            d = random_directive(rng, **traits)
        return d, form

    # explore: propose a structurally different strategy, honoring
    # meta-recommendations about untried high-value behaviors
    def _rewrite(self, parent, ctx, rng):
        for rec in ctx.recommendations:
            if rec.get("kind") == "try_behavior":
                cand = dataclasses.replace(
                    parent, backend=rec["backend"], placement=rec["placement"],
                    completion=rec["completion"])
                cand = self._retune(cand, ctx, rng)
                if is_valid(cand, **ctx.traits) and rng.random() < 0.7:
                    return cand
        d = random_directive(rng, **ctx.traits)
        # bias exploration toward overlap-capable placements — the hardware
        # context says communication sits on the critical path
        if rng.random() < 0.6 and d.placement == "DEFERRED":
            for p in ("TILE_PIPELINED", "STREAM_SPLIT", "TILE_FUSED"):
                cand = dataclasses.replace(d, placement=p, contexts=2)
                if is_valid(cand, **ctx.traits):
                    d = cand
                    break
        return self._retune(d, ctx, rng)

    def _crossover(self, parent, samples, rng, traits):
        other = rng.choice(samples).directive
        kw = {}
        for dim in DIMENSIONS:
            kw[dim] = getattr(other if rng.random() < 0.5 else parent, dim)
        merged = dict(parent.tunables)
        merged.update({k: v for k, v in other.tunables if rng.random() < 0.5})
        d = Directive(**kw, tunables=tuple(sorted(merged.items())))
        return d if is_valid(d, **traits) else parent

    # exploit: one semantically-targeted move
    def _diff(self, parent, ctx, rng):
        fb = (ctx.parent.result.diagnostic if ctx.parent.result else "") or ""
        # feedback routing: verification failures point at sync dims
        if "verify failed" in fb or "non-finite" in fb:
            dims = ("completion", "ordering", "contexts")
        elif "invalid directive" in fb or "build" in fb:
            dims = ("backend", "placement")
        else:
            # performance refinement: prefer tunables, then overlap dims
            if ctx.tunable_space and rng.random() < 0.5:
                name = rng.choice(sorted(ctx.tunable_space))
                cand = self._apply_tunable(parent, name, ctx, rng)
                if cand is not None:
                    return cand
            dims = _BOTTLENECK_DIMS.get(self._bottleneck(ctx),
                                        tuple(DIMENSIONS)[:6])
        dim = rng.choice(dims)
        options = [v for v in DIMENSIONS[dim] if v != getattr(parent, dim)]
        for v in rng.sample(options, len(options)):
            d = dataclasses.replace(parent, **{dim: v})
            if dim == "placement" and v in ("TILE_PIPELINED",) \
                    and d.contexts < 2:
                d = dataclasses.replace(d, contexts=2)
            if is_valid(d, **ctx.traits):
                return d
        return parent

    @staticmethod
    def _set_knob(d, name, value, ctx):
        """Set one knob. ``contexts`` lives on the directive itself (a
        dimension of C), every other knob in the tunables tuple; returns
        None when the move produces an invalid directive."""
        if name == "contexts":
            cand = dataclasses.replace(d, contexts=value)
            return cand if is_valid(cand, **ctx.traits) else None
        return d.with_tunable(name, value)

    def _apply_tunable(self, parent, name, ctx, rng):
        """One diff-patch knob move; returns None when no distinct valid
        value exists."""
        cur = parent.contexts if name == "contexts" else parent.tunable(name)
        vals = [v for v in ctx.tunable_space[name] if v != cur]
        for v in rng.sample(vals, len(vals)):
            cand = self._set_knob(parent, name, v, ctx)
            if cand is not None:
                return cand
        return None

    def _retune(self, d, ctx, rng):
        for name, vals in ctx.tunable_space.items():
            if rng.random() < 0.5:
                cand = self._set_knob(d, name, rng.choice(list(vals)), ctx)
                if cand is not None:
                    d = cand
        return d

    def _bottleneck(self, ctx):
        for rec in ctx.recommendations:
            if rec.get("kind") == "bottleneck":
                return rec["which"]
        return "collective"


# --------------------------------------------------------------------- LLM

PROMPT_TEMPLATE = """You are optimizing a compute-communication co-designed
TPU program. Emit an OPTIMIZATION DIRECTIVE selecting one value per dimension
— nothing else. Dimensions and allowed values:
{space}

Hardware context:
{hardware}

Backend-conditioned API context:
{api_context}

Strategy knowledge: kernel-level fusion suits iterative fine-grained
exchanges; stream-level overlap suits bulk transfers between large compute
phases; split put/wait suits pipelines where the sender has useful work
before confirming delivery.

Parent directive (score {score:.2f}):
{parent}
Feedback: {feedback}
Archive inspirations:
{inspirations}
Meta-recommendations: {recommendations}
Phase: {phase} (explore -> propose a structurally different strategy;
exploit -> refine one dimension or tunable of the parent).
"""

GIN_CONTEXT = ("PALLAS_RDMA (device-initiated): pltpu.make_async_remote_copy "
               "issues a one-sided put over ICI; .wait()/semaphores signal "
               "completion; transfers may overlap kernel compute. Rules: "
               "waits must drain every started DMA; a buffer slot may be "
               "reused only after the downstream reader acknowledges it.")
XLA_CONTEXT = ("XLA_COLLECTIVE (graph-level): jax.lax collectives are "
               "barrier-semantic ops scheduled by XLA; overlap requires "
               "dependence-free program structure (STREAM_SPLIT).")


class LLMMutator(MutationOperator):
    """Paper-faithful prompt assembly; delegates generation to ``llm_fn``
    (str -> str). Offline containers use HeuristicMutator instead."""

    def __init__(self, llm_fn=None, temperature_explore=1.0,
                 temperature_exploit=0.2):
        self.llm_fn = llm_fn
        self.t_hi = temperature_explore
        self.t_lo = temperature_exploit

    def build_prompt(self, ctx: MutationContext) -> str:
        parent = ctx.parent
        space = "\n".join(f"  {k}: {v}" for k, v in DIMENSIONS.items())
        api = GIN_CONTEXT if parent.directive.backend != "XLA_COLLECTIVE" \
            else XLA_CONTEXT
        insp = "\n".join(c.directive.render() for c in ctx.archive_samples) \
            or "(none)"
        return PROMPT_TEMPLATE.format(
            space=space,
            hardware=getattr(ctx.hardware, "topology_summary", "(unknown)"),
            api_context=api, score=parent.score, parent=parent.directive.render(),
            feedback=(parent.result.diagnostic if parent.result else ""),
            inspirations=insp, recommendations=ctx.recommendations,
            phase=ctx.phase)

    def propose(self, ctx, rng):
        if self.llm_fn is None:
            raise RuntimeError(
                "LLMMutator requires an llm_fn (API access); this container "
                "is offline — use HeuristicMutator.")
        text = self.llm_fn(self.build_prompt(ctx))
        d = parse_directive(text, fallback=ctx.parent.directive)
        return d, "llm"


def parse_directive(text: str, fallback: Directive) -> Directive:
    """Parse a rendered directive block back into a Directive."""
    kw = {}
    tun = dict(fallback.tunables)
    for line in text.splitlines():
        parts = line.strip().split("=")
        if len(parts) != 2:
            continue
        k = parts[0].strip().split()[-1]
        v = parts[1].strip()
        if k in DIMENSIONS:
            kw[k] = int(v) if k == "contexts" else v
        elif k and line.strip().startswith("tunable"):
            name = line.strip().split()[1]
            try:
                tun[name] = int(v)
            except ValueError:
                pass
    return dataclasses.replace(fallback, **kw,
                               tunables=tuple(sorted(tun.items())))
