"""Request scheduler for continuous batching — the admission/assembly
policy in front of :class:`repro.serve.engine.Engine`.

The serving loop is step-synchronous: each engine step spends a **token
budget** (decode slots cost 1 token, an admission costs the request's
whole prompt), and :meth:`Scheduler.plan_step` decides how to spend it:

* **decode claims** — active requests claim one decode token each, in
  admission order, rotated after every step so that when the budget (or
  ``max_batch``) is smaller than the active set, the unserved requests go
  first next step — no request starves.
* **admission** — strict head-of-line FIFO over the waiting queue: the
  oldest waiting request is admitted iff its full prompt still fits in
  the step's remaining budget and a batch slot is free. Younger requests
  never jump the queue (the no-starvation guarantee extends to waiting
  requests).

The scheduler owns policy only — queues, ordering, and the budget
invariant (per-step spent tokens ≤ ``token_budget``, checked in
tier-1 ``tests/test_serving.py``). The engine owns all model state
(caches, keys, sampled tokens) in its ``serve`` loop and reports
completions back via :meth:`finish`. Instrumented through
:class:`repro.core.telemetry.MetricsRegistry` (``sched.*`` series).
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["Request", "Scheduler"]


@dataclass
class Request:
    """One user request: a prompt (token ids) and a decode allowance."""
    rid: int
    prompt: tuple
    max_new_tokens: int = 8

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")

    @property
    def prompt_len(self):
        return len(self.prompt)


@dataclass
class Scheduler:
    token_budget: int = 32
    max_batch: int = 8
    metrics: object = None
    waiting: list = field(default_factory=list)    # FIFO of Request
    active: dict = field(default_factory=dict)     # rid -> Request
    _order: list = field(default_factory=list)     # admission order, rotated

    def __post_init__(self):
        if self.token_budget < 1:
            raise ValueError("token_budget must be >= 1")

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request):
        """Queue a request. A prompt longer than the whole budget could
        never be admitted — reject it at the door instead of starving the
        queue behind it."""
        if req.prompt_len > self.token_budget:
            raise ValueError(
                f"request {req.rid}: prompt_len {req.prompt_len} exceeds "
                f"token_budget {self.token_budget}")
        if req.rid in self.active or any(w.rid == req.rid
                                         for w in self.waiting):
            raise ValueError(f"duplicate rid {req.rid}")
        self.waiting.append(req)
        if self.metrics is not None:
            self.metrics.counter("sched.submitted").inc()

    def finish(self, rid):
        """Engine reports a request complete: free its batch slot."""
        self.active.pop(rid)
        self._order.remove(rid)
        if self.metrics is not None:
            self.metrics.counter("sched.finished").inc()

    @property
    def pending(self):
        return bool(self.waiting or self.active)

    # --------------------------------------------------------------- policy
    def plan_step(self):
        """Plan one engine step under the token budget.

        Returns ``(decode_rids, admits)``: active requests that decode one
        token this step (≤ ``max_batch``, ≤ budget), and newly admitted
        requests (FIFO, each costing its prompt length). Invariant:
        ``len(decode_rids) + sum(prompt_len)  <=  token_budget``.
        """
        used = 0
        decode = []
        for rid in self._order:
            if len(decode) >= self.max_batch or used >= self.token_budget:
                break
            decode.append(rid)
            used += 1
        # rotate the served prefix to the back: requests that missed this
        # step head the order next step (starvation-freedom under a budget
        # smaller than the active set)
        k = len(decode)
        if 0 < k < len(self._order):
            self._order = self._order[k:] + self._order[:k]

        admits = []
        while (self.waiting
               and len(self.active) + len(admits) < self.max_batch
               and used + self.waiting[0].prompt_len <= self.token_budget):
            req = self.waiting.pop(0)
            admits.append(req)
            used += req.prompt_len
        for req in admits:
            self.active[req.rid] = req
            self._order.append(req.rid)

        if self.metrics is not None:
            self.metrics.histogram("sched.step_tokens").observe(used)
            self.metrics.gauge("sched.active").set(len(self.active))
            self.metrics.gauge("sched.waiting").set(len(self.waiting))
            if admits:
                self.metrics.counter("sched.admitted").inc(len(admits))
        assert used <= self.token_budget, (used, self.token_budget)
        return decode, admits
