from repro.serve.engine import ServeConfig, Engine
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ServeConfig", "Engine", "Request", "Scheduler"]
