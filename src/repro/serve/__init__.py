from repro.serve.engine import ServeConfig, Engine

__all__ = ["ServeConfig", "Engine"]
