"""Batched serving engine: prefill -> KV cache -> greedy/sampled decode.

Also implements **disaggregated prefill/decode** (the paper's KV-transfer
workload at system level): ``prefill_remote`` runs prefill as if on a prefill
tier and ships the cache to the decode tier — on real hardware via the
device-initiated kv_shuttle kernel; the engine-level handoff here is the
cache pytree handover, with the kernel exercised by the workload benchmarks.

Sampling draws from ONE stateful key stream: the engine seeds
``PRNGKey(seed)`` once and splits a fresh subkey per sample, threaded
through prefill/generate/decode_from_handoff — two temperature>0 batches
never sample with the identical key (the old per-call ``PRNGKey(seed)``
re-creation did exactly that), while re-constructing the engine with the
same seed reproduces the stream exactly.

An optional :class:`repro.train.fault_tolerance.StragglerWatchdog` receives
per-decode-step wall times — the serving side of the elastic fault loop
(``should_replace`` -> drop the rank, degrade the schedules, keep serving).

Serving metrics ride a :class:`repro.core.telemetry.MetricsRegistry`
(``metrics=``, one created per engine otherwise): decode step-latency
histogram, prefill latency, tokens generated, decode steps, and watchdog
incidents — ``engine.metrics.snapshot()`` is the JSON-ready view.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.telemetry import MetricsRegistry
from repro.models import StepOptions, decode_step, prefill_step


def _leaf_name(path):
    return getattr(path[-1], "key", None)


def _stack_caches(caches):
    """Batch per-request (B=1) decode caches into one engine cache.

    Attention leaves (k/v/ck/cv) carry batch on axis 1; kpos is shared
    across the batch (all grouped requests sit at the same position), so
    any one copy is the group's. Recurrent state leaves are not batched
    here — grouped decode serves attention archs."""
    if len(caches) == 1:
        return caches[0]

    def cat(path, *xs):
        name = _leaf_name(path)
        if name in ("k", "v", "ck", "cv"):
            return jnp.concatenate(xs, axis=1)
        if name == "kpos":
            return xs[0]
        raise NotImplementedError(
            f"serve: cannot batch cache leaf {name!r} (recurrent state?)")

    return jax.tree_util.tree_map_with_path(cat, caches[0], *caches[1:])


def _split_cache(cache, n):
    """Inverse of :func:`_stack_caches`: n per-request (B=1) caches."""
    if n == 1:
        return [cache]

    def cut(i):
        def f(path, x):
            name = _leaf_name(path)
            if name in ("k", "v", "ck", "cv"):
                return x[:, i:i + 1]
            if name == "kpos":
                return x
            raise NotImplementedError(
                f"serve: cannot split cache leaf {name!r}")
        return jax.tree_util.tree_map_with_path(f, cache)

    return [cut(i) for i in range(n)]


@dataclass
class ServeConfig:
    max_seq: int = 512
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    opts: StepOptions = None

    def __post_init__(self):
        if self.opts is None:
            self.opts = StepOptions(remat=False)


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig, rules=None,
                 watchdog=None, metrics=None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.rules = rules
        self.watchdog = watchdog          # optional StragglerWatchdog
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._gen = 0                     # bumped by degrade()
        self._jit_steps()

    def _jit_steps(self):
        cfg, rules, scfg = self.cfg, self.rules, self.scfg
        self._prefill = jax.jit(
            lambda p, b: prefill_step(p, b, cfg, rules,
                                      seq_len=scfg.max_seq, opts=scfg.opts))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, rules,
                                             opts=scfg.opts))

    def degrade(self, devices):
        """Elastic serving: shrink onto the surviving devices and re-jit.

        Rebuilds a 1-D data mesh + :class:`Rules` of the same kind over
        ``devices`` (the serving deployment shape); a single survivor drops
        the engine to the local (unsharded) path. Per-request caches held
        by a running ``serve`` loop are replicated-small and re-placed by
        the next jitted step, so the loop keeps emitting tokens."""
        from repro.compat import make_mesh
        from repro.dist.sharding import Rules
        devices = list(devices)
        if self.rules is None or len(devices) <= 1:
            self.rules = None
        else:
            mesh = make_mesh((len(devices),), ("data",), devices=devices)
            self.rules = Rules(mesh, self.rules.kind,
                               long_context=self.rules.long_context)
        self.metrics.counter("serve.degrades").inc()
        self._gen += 1
        self._jit_steps()
        return self.rules

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / self.scfg.temperature).astype(jnp.int32)

    def _decode_one(self, cache, tok, pos):
        t0 = time.perf_counter()
        logits, cache = self._decode(self.params, cache, tok[:, None],
                                     jnp.int32(pos))
        tok = self._sample(logits, self._next_key())
        if self.watchdog is not None:
            jax.block_until_ready(tok)
            step_s = time.perf_counter() - t0
            if self.watchdog.record(step_s):
                self.metrics.counter("serve.watchdog_incidents").inc()
        else:
            step_s = time.perf_counter() - t0
        self.metrics.histogram("serve.decode_step_ms").observe(step_s * 1e3)
        self.metrics.counter("serve.decode_steps").inc()
        self.metrics.counter("serve.tokens_generated").inc(
            int(tok.shape[0]))
        return tok, cache

    def prefill(self, batch):
        """batch: {"tokens": (B, S0), ...} -> (first_token, cache, pos)."""
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        tok = self._sample(logits, self._next_key())
        jax.block_until_ready(tok)
        self.metrics.histogram("serve.prefill_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        self.metrics.counter("serve.prefills").inc()
        self.metrics.counter("serve.prefill_tokens").inc(
            int(batch["tokens"].shape[0] * batch["tokens"].shape[1]))
        return tok, cache, batch["tokens"].shape[1]

    def generate(self, batch, max_new_tokens):
        """Batched greedy/sampled generation. Returns (B, new) tokens."""
        tok, cache, pos = self.prefill(batch)
        out = [tok]
        for i in range(max_new_tokens - 1):
            tok, cache = self._decode_one(cache, tok, pos + i)
            out.append(tok)
        return jnp.stack(out, axis=1)

    # ---- continuous batching --------------------------------------------
    def _req_key(self, rid):
        # per-request stream: independent of batch composition and of the
        # engine-level stream used by generate()
        return jax.random.fold_in(jax.random.PRNGKey(self.scfg.seed), rid)

    def serve(self, scheduler, on_step=None, max_steps=10_000):
        """Continuous-batching loop over a :class:`repro.serve.scheduler.
        Scheduler`: each step decodes the scheduler's claims (grouped by
        position so one jitted ``decode_step`` serves each group) and
        prefills its admissions. Sampling uses per-request key streams
        (``fold_in(seed, rid)``), so a request's tokens do not depend on
        which other requests share its batch. ``on_step(step_no, engine)``
        runs after every step — the fault-injection hook for elastic
        serving tests. Returns ``{rid: (tokens,) int32}``."""
        states, done = {}, {}
        step_no = 0
        while scheduler.pending:
            if step_no >= max_steps:
                raise RuntimeError(
                    f"serve: {max_steps} steps with requests still pending")
            decode_rids, admits = scheduler.plan_step()

            groups = {}
            for rid in decode_rids:
                groups.setdefault(states[rid]["pos"], []).append(rid)
            for pos, rids in sorted(groups.items()):
                toks = jnp.concatenate([states[r]["tok"] for r in rids])
                cache = _stack_caches([states[r]["cache"] for r in rids])
                t0 = time.perf_counter()
                logits, cache = self._decode(self.params, cache,
                                             toks[:, None], jnp.int32(pos))
                jax.block_until_ready(logits)
                step_s = time.perf_counter() - t0
                if self.watchdog is not None and self.watchdog.record(step_s):
                    self.metrics.counter("serve.watchdog_incidents").inc()
                self.metrics.histogram("serve.decode_step_ms").observe(
                    step_s * 1e3)
                self.metrics.counter("serve.decode_steps").inc()
                self.metrics.counter("serve.tokens_generated").inc(len(rids))
                parts = _split_cache(cache, len(rids))
                for i, rid in enumerate(rids):
                    st = states[rid]
                    st["key"], sub = jax.random.split(st["key"])
                    tok = self._sample(logits[i:i + 1], sub)
                    st.update(tok=tok, cache=parts[i], pos=pos + 1)
                    st["out"].append(int(tok[0]))

            for req in admits:
                batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
                logits, cache = self._prefill(self.params, batch)
                key, sub = jax.random.split(self._req_key(req.rid))
                tok = self._sample(logits, sub)
                states[req.rid] = {"cache": cache, "pos": req.prompt_len,
                                   "tok": tok, "key": key,
                                   "out": [int(tok[0])]}
                self.metrics.counter("serve.prefills").inc()
                self.metrics.counter("serve.prefill_tokens").inc(
                    req.prompt_len)

            for rid in list(states):
                if len(states[rid]["out"]) >= \
                        scheduler.active[rid].max_new_tokens:
                    done[rid] = jnp.asarray(states.pop(rid)["out"],
                                            jnp.int32)
                    scheduler.finish(rid)

            self.metrics.counter("serve.steps").inc()
            if on_step is not None:
                gen = self._gen
                on_step(step_no, self)
                if self._gen != gen:
                    # degraded mid-run: pull request state off the old mesh
                    # so the re-jitted steps re-place it on the new one
                    for st in states.values():
                        st["cache"] = jax.device_get(st["cache"])
                        st["tok"] = jax.device_get(st["tok"])
            step_no += 1
        return done

    # ---- disaggregated prefill/decode tiers ------------------------------
    def _shuttle_cache(self, cache, mesh, **kw):
        """Push every attention KV block through the device-initiated
        ``kv_cache_shuttle`` kernel (prefill rank 0 → decode rank 1 of
        ``mesh``) and return the cache rebuilt from what landed on the
        decode rank. Paired leaves ([k,v] and [ck,cv]) ride one shuttle
        each as stacked ``[K; V]`` row blocks."""
        from repro.kernels.kv_shuttle import kv_cache_shuttle
        out = {}
        for name, block in cache.items():
            if not (isinstance(block, dict) and "k" in block):
                raise NotImplementedError(
                    f"serve: cannot shuttle cache block {name!r}")
            nb = dict(block)
            for a, b in (("k", "v"), ("ck", "cv")):
                if a not in block:
                    continue
                ka, vb = block[a], block[b]
                rows = lambda x: x.reshape(-1, x.shape[-1])
                stacked = jnp.concatenate([rows(ka), rows(vb)], axis=0)
                kv = jnp.stack([stacked, jnp.zeros_like(stacked)])
                ko, vo = kv_cache_shuttle(kv, mesh, **kw)
                nb[a] = ko[1].reshape(ka.shape).astype(ka.dtype)
                nb[b] = vo[1].reshape(vb.shape).astype(vb.dtype)
            out[name] = nb
        return out

    def prefill_remote(self, batch, shuttle_mesh=None, **shuttle_kw):
        """Prefill-tier step: returns the cache pytree to ship to decode.
        With ``shuttle_mesh`` (a 2-rank mesh) the KV blocks actually ride
        the device-initiated kv_shuttle kernel — prefill rank pushes, the
        handoff cache is what lands on the decode rank; without it the
        engine hands over the pytree directly."""
        tok, cache, pos = self.prefill(batch)
        if shuttle_mesh is not None:
            cache = self._shuttle_cache(cache, shuttle_mesh, **shuttle_kw)
        self.metrics.counter("serve.kv_handoffs").inc()
        return {"first_token": tok, "cache": cache, "pos": pos}

    def decode_from_handoff(self, handoff, max_new_tokens):
        tok = handoff["first_token"]
        cache = handoff["cache"]
        pos = handoff["pos"]
        out = [tok]
        for i in range(max_new_tokens - 1):
            tok, cache = self._decode_one(cache, tok, pos + i)
            out.append(tok)
        return jnp.stack(out, axis=1)
