"""Batched serving engine: prefill -> KV cache -> greedy/sampled decode.

Also implements **disaggregated prefill/decode** (the paper's KV-transfer
workload at system level): ``prefill_remote`` runs prefill as if on a prefill
tier and ships the cache to the decode tier — on real hardware via the
device-initiated kv_shuttle kernel; the engine-level handoff here is the
cache pytree handover, with the kernel exercised by the workload benchmarks.

Sampling draws from ONE stateful key stream: the engine seeds
``PRNGKey(seed)`` once and splits a fresh subkey per sample, threaded
through prefill/generate/decode_from_handoff — two temperature>0 batches
never sample with the identical key (the old per-call ``PRNGKey(seed)``
re-creation did exactly that), while re-constructing the engine with the
same seed reproduces the stream exactly.

An optional :class:`repro.train.fault_tolerance.StragglerWatchdog` receives
per-decode-step wall times — the serving side of the elastic fault loop
(``should_replace`` -> drop the rank, degrade the schedules, keep serving).

Serving metrics ride a :class:`repro.core.telemetry.MetricsRegistry`
(``metrics=``, one created per engine otherwise): decode step-latency
histogram, prefill latency, tokens generated, decode steps, and watchdog
incidents — ``engine.metrics.snapshot()`` is the JSON-ready view.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.telemetry import MetricsRegistry
from repro.models import StepOptions, decode_step, prefill_step


@dataclass
class ServeConfig:
    max_seq: int = 512
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    opts: StepOptions = None

    def __post_init__(self):
        if self.opts is None:
            self.opts = StepOptions(remat=False)


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig, rules=None,
                 watchdog=None, metrics=None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.rules = rules
        self.watchdog = watchdog          # optional StragglerWatchdog
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._prefill = jax.jit(
            lambda p, b: prefill_step(p, b, cfg, rules,
                                      seq_len=serve_cfg.max_seq,
                                      opts=serve_cfg.opts))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, rules,
                                             opts=serve_cfg.opts))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / self.scfg.temperature).astype(jnp.int32)

    def _decode_one(self, cache, tok, pos):
        t0 = time.perf_counter()
        logits, cache = self._decode(self.params, cache, tok[:, None],
                                     jnp.int32(pos))
        tok = self._sample(logits, self._next_key())
        if self.watchdog is not None:
            jax.block_until_ready(tok)
            step_s = time.perf_counter() - t0
            if self.watchdog.record(step_s):
                self.metrics.counter("serve.watchdog_incidents").inc()
        else:
            step_s = time.perf_counter() - t0
        self.metrics.histogram("serve.decode_step_ms").observe(step_s * 1e3)
        self.metrics.counter("serve.decode_steps").inc()
        self.metrics.counter("serve.tokens_generated").inc(
            int(tok.shape[0]))
        return tok, cache

    def prefill(self, batch):
        """batch: {"tokens": (B, S0), ...} -> (first_token, cache, pos)."""
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        tok = self._sample(logits, self._next_key())
        jax.block_until_ready(tok)
        self.metrics.histogram("serve.prefill_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        self.metrics.counter("serve.prefills").inc()
        self.metrics.counter("serve.prefill_tokens").inc(
            int(batch["tokens"].shape[0] * batch["tokens"].shape[1]))
        return tok, cache, batch["tokens"].shape[1]

    def generate(self, batch, max_new_tokens):
        """Batched greedy/sampled generation. Returns (B, new) tokens."""
        tok, cache, pos = self.prefill(batch)
        out = [tok]
        for i in range(max_new_tokens - 1):
            tok, cache = self._decode_one(cache, tok, pos + i)
            out.append(tok)
        return jnp.stack(out, axis=1)

    # ---- disaggregated prefill/decode tiers ------------------------------
    def prefill_remote(self, batch):
        """Prefill-tier step: returns the cache pytree to ship to decode.
        On hardware the KV blocks ride the device-initiated kv_shuttle
        (repro.kernels.kv_shuttle); the engine hands over the pytree."""
        tok, cache, pos = self.prefill(batch)
        self.metrics.counter("serve.kv_handoffs").inc()
        return {"first_token": tok, "cache": cache, "pos": pos}

    def decode_from_handoff(self, handoff, max_new_tokens):
        tok = handoff["first_token"]
        cache = handoff["cache"]
        pos = handoff["pos"]
        out = [tok]
        for i in range(max_new_tokens - 1):
            tok, cache = self._decode_one(cache, tok, pos + i)
            out.append(tok)
        return jnp.stack(out, axis=1)
