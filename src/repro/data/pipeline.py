"""Deterministic, index-addressable synthetic token pipeline.

Stateless-by-construction: batch(i) is a pure function of (seed, i), so a
restarted job resumes mid-epoch exactly by storing only the step counter in
the checkpoint — no iterator state, no data-loss window (the fault-tolerance
story depends on this). Supports host-sharded loading (each host materializes
only its batch shard) and background prefetch.

The synthetic stream is a mixture of Zipfian unigrams and a deterministic
"copy task" structure so the loss actually decreases during the e2e examples.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_period: int = 16          # structure: token repeats every period
    frames: int = 0                # enc-dec stub frames
    patches: int = 0               # vlm stub patch tokens
    d_model: int = 0


class SyntheticTokenPipeline:
    def __init__(self, cfg: DataConfig, *, host_index=0, num_hosts=1,
                 prefetch=2):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self._q = None
        self._prefetch = prefetch
        # zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    # ------------------------------------------------------------ batches
    def batch(self, step: int):
        """Global batch for `step`, restricted to this host's rows."""
        cfg = self.cfg
        rows = []
        lo = self.host_index * self.local_batch
        for r in range(lo, lo + self.local_batch):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, r]))
            base = rng.choice(cfg.vocab_size, size=cfg.seq_len, p=self._p)
            # learnable structure: the stream is periodic with copy_period
            # (token t == token t - copy_period for all t >= copy_period)
            idx = np.arange(cfg.seq_len)
            base = base[idx % cfg.copy_period]
            rows.append(base)
        tokens = np.stack(rows).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:],
                                 np.full((len(rows), 1), -1, np.int32)], 1)
        out = {"tokens": tokens, "labels": labels}
        if cfg.frames:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 10**6]))
            out["frames"] = rng.standard_normal(
                (self.local_batch, cfg.frames, cfg.d_model),
                dtype=np.float32).astype(np.float32)
        if cfg.patches:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 2 * 10**6]))
            out["patches"] = rng.standard_normal(
                (self.local_batch, cfg.patches, cfg.d_model),
                dtype=np.float32)
            out["labels"][:, :cfg.patches] = -1
        return out

    # ----------------------------------------------------------- prefetch
    def start_prefetch(self, first_step: int):
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop = False

        def worker():
            s = first_step
            while not self._stop:
                try:
                    self._q.put((s, self.batch(s)), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next_prefetched(self):
        s, b = self._q.get()
        return s, b

    def stop(self):
        self._stop = True
