"""repro: CUCo (compute/communication co-design) reproduced as a JAX/TPU
framework - models, distribution, training/serving substrate, and the
co-design search engine (repro.core)."""

__version__ = "1.0.0"
