#!/usr/bin/env python
"""Standalone schedule sanitizer CLI (CI `verify-lint` job).

Runs the l0 static verifier (``src/repro/core/verify.py``) over every
registered workload x expert-system design point — the exact programs the
cascade's l0 level would check — plus the degraded (dropped-rank)
variants and the full ``TUNABLES['contexts']`` window-depth grid.  With
``--mutations`` it additionally replays the seeded-mutation corpus and
requires every bug class to be flagged with its class-specific
diagnostic.

Usage:
    PYTHONPATH=src python tools/schedule_lint.py [--mutations] [--json F]
                                                 [--catalog] [--quiet]

Exit code 1 on any clean-point failure or any uncaught mutation.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def lint_points(quiet=False):
    """Lint every (workload, expert-system point) pair: the directive's
    own program at its ``contexts`` plus the schedule swept across the
    ``TUNABLES`` contexts grid and its degraded one-rank-down variant.
    Returns (rows, failures)."""
    from repro.core.design_space import (CONSERVATIVE, EXPERT_SYSTEMS,
                                         TUNABLES)
    from repro.core.verify import verify_directive, verify_schedule
    from repro.workloads import WORKLOADS, get_workload

    points = dict(EXPERT_SYSTEMS)
    points["CONSERVATIVE"] = CONSERVATIVE
    rows, failures = [], []
    for wname in sorted(WORKLOADS):
        wl = get_workload(wname)
        for pname, d in sorted(points.items()):
            t0 = time.perf_counter()
            viol = wl.check(d, None)
            if viol:
                status, detail = "invalid", "; ".join(viol)
            else:
                rep = verify_directive(wl, d)
                if rep is None:
                    status, detail = "vacuous", "no collective schedule"
                else:
                    # sweep the full contexts grid + the degrade splice
                    sched = wl.collective_schedule(d)
                    knobs = wl.kernel_knobs(d)
                    grid = verify_schedule(sched, knobs=knobs)
                    reps = [rep, grid]
                    if sched.n > 2:
                        live = tuple(range(sched.n - 1))
                        reps.append(verify_schedule(
                            sched.degrade(live), knobs=knobs,
                            contexts=tuple(TUNABLES["contexts"]),
                            parent=sched, live=live))
                    bad = [r for r in reps if not r.ok]
                    status = "fail" if bad else "ok"
                    detail = "; ".join(r.summary() for r in bad) if bad \
                        else f"{sum(r.checked.get('ops', 0) for r in reps)} ops"
            row = {"workload": wname, "point": pname, "status": status,
                   "detail": detail,
                   "elapsed_ms": (time.perf_counter() - t0) * 1e3}
            rows.append(row)
            if status == "fail":
                failures.append(row)
            if not quiet:
                print(f"  {wname:<16} {pname:<16} {status:<8} "
                      f"{row['elapsed_ms']:7.1f} ms  {detail[:90]}")
    return rows, failures


def lint_mutations(quiet=False):
    """Replay the seeded-mutation corpus: every class must be rejected
    with its expected checker code as the *first* diagnostic."""
    from repro.core.verify import mutation_corpus

    rows, failures = [], []
    for e in mutation_corpus():
        t0 = time.perf_counter()
        rep = e["run"]()
        first = rep.errors[0].code if rep.errors else None
        caught = (not rep.ok) and first == e["expect"]
        row = {"class": e["cls"], "expect": e["expect"], "first": first,
               "caught": caught, "diagnostic": rep.summary(limit=1),
               "elapsed_ms": (time.perf_counter() - t0) * 1e3}
        rows.append(row)
        if not caught:
            failures.append(row)
        if not quiet:
            mark = "caught" if caught else "MISSED"
            print(f"  {e['cls']:<24} -> {str(first):<20} {mark:<7} "
                  f"{row['elapsed_ms']:6.1f} ms")
    return rows, failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mutations", action="store_true",
                    help="also replay the seeded-mutation corpus")
    ap.add_argument("--json", metavar="FILE",
                    help="write the full report as JSON")
    ap.add_argument("--catalog", action="store_true",
                    help="print the checker catalog and exit")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.catalog:
        from repro.core.verify import CHECKS
        for code, desc in CHECKS.items():
            print(f"{code:<22} {desc}")
        return 0

    if not args.quiet:
        print("schedule_lint: workload x expert-system points")
    rows, failures = lint_points(quiet=args.quiet)
    report = {"schema": "schedule-lint/v1", "points": rows}
    if args.mutations:
        if not args.quiet:
            print("schedule_lint: seeded-mutation corpus")
        mrows, mfail = lint_mutations(quiet=args.quiet)
        report["mutations"] = mrows
        failures += mfail
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_vac = sum(r["status"] in ("vacuous", "invalid") for r in rows)
    print(f"schedule_lint: {n_ok} points verified, {n_vac} vacuous/invalid, "
          f"{len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
