"""Docs consistency check (CI `docs` job): every internal markdown link and
code reference in README.md / docs/*.md must resolve against the repo.

Checked:
  * relative markdown links ``[text](path)`` (external http/mailto and
    pure-anchor links are skipped; ``#fragment`` suffixes are stripped);
  * backtick code spans that look like repo paths (``src/...``,
    ``tests/...``, ...), optionally with a ``::symbol`` suffix — the file
    must exist and, for ``path.py::name``, define the symbol;
  * backtick dotted-module references (``repro.kernels.moe_dispatch``) —
    the module file must exist under src/.

Exit code 1 with a per-file report on any dangling reference.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_PREFIXES = ("src/", "tests/", "benchmarks/", "docs/", "examples/",
                 "tools/", ".github/")
PATH_SPAN_RE = re.compile(
    r"^(?:%s)[\w./\-]*(?:::[\w.]+)?$" % "|".join(re.escape(p)
                                                 for p in PATH_PREFIXES))
MODULE_SPAN_RE = re.compile(r"^repro(\.[A-Za-z_][\w]*)+$")


def doc_files():
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_link(md: pathlib.Path, target: str):
    if target.startswith(("http://", "https://", "mailto:")):
        return None
    if target.startswith("#"):                    # intra-document anchor
        return None
    path = target.split("#")[0]
    resolved = (md.parent / path).resolve()
    if not resolved.exists():
        return f"dangling link ({target})"
    return None


def check_code_span(span: str):
    if PATH_SPAN_RE.match(span):
        path, _, symbol = span.partition("::")
        f = ROOT / path
        if not f.exists():
            return f"missing path ({span})"
        if symbol and symbol.split(".")[0] not in f.read_text():
            return f"symbol not found ({span})"
        return None
    if MODULE_SPAN_RE.match(span):
        # resolve the longest dotted prefix that is a module; any remainder
        # must be a symbol defined in that module's file
        parts = span.split(".")
        for k in range(len(parts), 0, -1):
            rel = "/".join(parts[:k])
            f = (ROOT / "src" / rel).with_suffix(".py")
            if not f.exists():
                f = ROOT / "src" / rel / "__init__.py"
            if f.exists():
                rest = parts[k:]
                if rest and rest[0] not in f.read_text():
                    return f"symbol not found ({span})"
                return None
        return f"missing module ({span})"
    return None


def main() -> int:
    problems = []
    for md in doc_files():
        text = md.read_text()
        rel = md.relative_to(ROOT)
        for m in LINK_RE.finditer(text):
            err = check_link(md, m.group(1))
            if err:
                problems.append(f"{rel}: {err}")
        for m in CODE_RE.finditer(text):
            err = check_code_span(m.group(1).strip())
            if err:
                problems.append(f"{rel}: {err}")
    if problems:
        print("docs check FAILED:")
        for p in problems:
            print("  " + p)
        return 1
    print(f"docs check ok ({len(doc_files())} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
