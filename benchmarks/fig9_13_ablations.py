"""Paper Figures 9-13 (ablations) on the ring-attention workload (the
richest valid design space: 2 backends x 4 placements x completions x
orderings x buffering):

  fig9   — naive iterative prompting (single chain, diff-only, no
           population/archive/meta) vs full CUCo.
  fig10/11 — fast-path + slow-path vs slow-path-only: random unverified
           island seed AND an unbounded mutation operator (the paper's
           "unconstrained generation" regime) — wasted-evaluation fraction.
  fig12/13 — two-phase explore->exploit vs exploit-only schedule (best score
           + MAP-Elites behavior coverage).
"""
import dataclasses
import random

from repro.core import (CONSERVATIVE, CascadeEvaluator, Candidate,
                        SlowPathConfig, extract_hardware_context, fast_path,
                        slow_path, random_directive)
from repro.core.mutation import HeuristicMutator, MutationContext
from repro.workloads import get_workload

GENS = 10


def _workload(mesh):
    return get_workload("ring_attention", n_dev=mesh.shape["x"], BH=16,
                        seq=8192, hd=64)


def naive_iterative(w, mesh, hw, gens, seed=0):
    """Single-program refinement: diff patches on the current best only —
    no islands, no crossover, no archive, no meta-recommendations.
    Returns (best, evals_to_best)."""
    rng = random.Random(seed)
    ev = CascadeEvaluator(w, mesh, hw)
    mut = HeuristicMutator()
    cur = Candidate(directive=CONSERVATIVE)
    cur.result = ev.evaluate(cur)
    best = cur
    evals_to_best = 1
    for g in range(gens * 3):          # same total evaluation budget
        ctx = MutationContext(parent=best, phase="exploit",
                              traits=w.traits(hw), tunable_space={})
        d, _ = mut.propose(ctx, rng)
        child = Candidate(directive=d, gen=g)
        child.result = ev.evaluate(child)
        if child.score > best.score * 1.0001:
            best = child
            evals_to_best = g + 2
    return best, evals_to_best


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    mesh = mesh or make_mesh((1,), ("x",))
    hw = extract_hardware_context(mesh)
    w = _workload(mesh)
    rows = []

    # --- fig 9: naive vs CUCo -------------------------------------------
    seed = fast_path(w, mesh, hw)
    res_full = slow_path(seed, mesh, hw, SlowPathConfig(
        islands=3, generations=GENS, seed=0))
    naive_best, naive_evals = naive_iterative(w, mesh, hw, GENS)
    t_naive = naive_best.result.t_model_ms
    t_full = res_full.best.result.t_model_ms
    series = res_full.best_per_generation()
    gens_to_best = next((g for g, s in series
                         if s >= res_full.best.score * 0.999), GENS)
    rows.append(("fig9/naive_prompting_ms", t_naive * 1e3,
                 f"best score {naive_best.score:.1f} after "
                 f"{naive_evals} evaluations"))
    rows.append(("fig9/cuco_ms", t_full * 1e3,
                 f"best score {res_full.best.score:.1f} by generation "
                 f"{gens_to_best} (paper: gen 3); speedup vs naive "
                 f"{t_naive / t_full:.3f}x"))

    # --- fig 10/11: fast-path + bounded-operator ablation -----------------
    rng = random.Random(42)
    no_fp_seed = dataclasses.replace(
        seed, directive=random_directive(rng, **w.traits(hw)))
    res_nofp = slow_path(no_fp_seed, mesh, hw,
                         SlowPathConfig(islands=3, generations=GENS, seed=0),
                         mutator=HeuristicMutator(bounded=False))
    waste_fp = sum(1 for r in res_full.db.records
                   if not (r.result and r.result.ok)) / len(res_full.db.records)
    waste_no = sum(1 for r in res_nofp.db.records
                   if not (r.result and r.result.ok)) / len(res_nofp.db.records)
    rows.append(("fig10/with_fastpath_best", res_full.best.score,
                 f"wasted_evals={waste_fp * 100:.0f}%"))
    rows.append(("fig11/without_fastpath_unbounded_best",
                 res_nofp.best.score,
                 f"wasted_evals={waste_no * 100:.0f}% (paper: 25% budget "
                 "wasted without the correctness-first stage)"))

    # --- fig 12/13: explore-exploit schedule ------------------------------
    res_exploit = slow_path(seed, mesh, hw, SlowPathConfig(
        islands=3, generations=GENS, explore_frac=0.0, seed=0))
    cov_2p = res_full.archive.coverage()
    cov_ex = res_exploit.archive.coverage()
    rows.append(("fig12/two_phase_best", res_full.best.score,
                 f"behaviors={cov_2p}"))
    rows.append(("fig13/exploit_only_best", res_exploit.best.score,
                 f"behaviors={cov_ex}; two-phase finds "
                 f"{cov_2p - cov_ex:+d} more behaviors"))
    return rows
