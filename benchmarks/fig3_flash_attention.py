"""Paper Figure 3: Flash Attention with Context Parallelism — host-driven
NCCL-analogue vs CUCo device-initiated fused ring kernel, over SEQ x HD.

Modeled latency at the paper's deployment (4 devices, ring) from the v5e
roofline composition; wall-clock on reduced shapes confirms the ordering.
"""
from repro.core import Directive, extract_hardware_context
from repro.workloads import get_workload


def run(mesh=None):
    import jax
    from repro.launch.mesh import make_mesh
    hw_mesh = mesh or make_mesh((1,), ("x",))
    hw = extract_hardware_context(hw_mesh)
    rows = []
    host = Directive("XLA_COLLECTIVE", placement="DEFERRED")
    cuco = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", contexts=2)
    for seq in (4096, 8192):
        for hd in (32, 64):
            w = get_workload("ring_attention", n_dev=4, BH=12 * 8, seq=seq,
                             hd=hd)
            t_host = w.analytic_cost(host, hw) * 1e3
            t_cuco = w.analytic_cost(cuco, hw) * 1e3
            rows.append((f"fig3/ring_attn_seq{seq}_hd{hd}_host",
                         t_host * 1e3, ""))
            rows.append((f"fig3/ring_attn_seq{seq}_hd{hd}_cuco",
                         t_cuco * 1e3, f"speedup={t_host / t_cuco:.3f}x"))
    return rows
