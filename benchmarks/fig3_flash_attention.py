"""Paper Figure 3: Flash Attention with Context Parallelism — host-driven
NCCL-analogue vs CUCo device-initiated ring kernels, over SEQ x HD.

Four points per shape, matching the fig4/fig6 row pattern: the host
baseline, the lazy-fence TILE_PIPELINED overlap point (cuco), and the two
kernelized ``RingSchedule`` realizations — the DEFERRED in-kernel rotation
and the FLUX-ring (TILE_FUSED + COUNTER per-chunk rotation). Modeled
latency at the paper's deployment (4 devices, ring) from the v5e roofline
composition; wall-clock on reduced shapes confirms the ordering.
"""
from repro.core import Directive, extract_hardware_context
from repro.core.design_space import EXPERT_SYSTEMS
from repro.workloads import get_workload

POINTS = (
    ("host", Directive("XLA_COLLECTIVE", placement="DEFERRED")),
    ("cuco", Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED",
                       contexts=2)),
    ("deferred", Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                           "KERNEL", "PER_PEER", "RELEASE", 2)),
    ("flux", EXPERT_SYSTEMS["FLUX"].with_tunable("kv_chunk", 64)),
)


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    hw_mesh = mesh or make_mesh((1,), ("x",))
    hw = extract_hardware_context(hw_mesh)
    rows = []
    for seq in (4096, 8192):
        for hd in (32, 64):
            w = get_workload("ring_attention", n_dev=4, BH=12 * 8, seq=seq,
                             hd=hd)
            costs = {name: w.analytic_cost(d, hw) * 1e3
                     for name, d in POINTS}
            for name, t in costs.items():
                note = "" if name == "host" \
                    else f"speedup={costs['host'] / t:.3f}x"
                rows.append((f"fig3/ring_attn_seq{seq}_hd{hd}_{name}",
                             t * 1e3, note))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="also write the table as bench-rows/v1 JSON")
    args = ap.parse_args()
    rows = run()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.out:
        from benchmarks.common import write_rows
        write_rows(args.out, rows)
