"""Paper Table 5: per-phase latency of the MoE layer — expert-library-style
sequential flow vs CUCo two-stream split vs the device-initiated Pallas
kernel (DeepEP point: tight wire, one fused launch, per-edge signals).
Phases: quantize / dispatch / compute / combine."""
from repro.core import (EXPERT_SYSTEMS, Directive,
                        extract_hardware_context)
from repro.workloads import get_workload
from repro.workloads.base import KERNEL_LAUNCH


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    hw = extract_hardware_context(mesh or make_mesh((1,), ("x",)))
    w = get_workload("moe_dispatch", n_dev=2, tokens_per_rank=6144, d=7168,
                     f=2048, skew=2.0)
    counts = w._counts(w.T)
    C = int(counts.max())
    n = w.n_dev
    chip = hw.chip
    # phase terms (rank 0 = busiest)
    recv = C * n
    t_comp = 3 * 2 * recv * w.d * w.f / chip.peak_bf16_flops * 1e3
    t_self = t_comp * counts[0] / recv
    t_remote = t_comp - t_self
    sent = C * (n - 1)
    t_disp = sent * w.d * 1 / chip.ici_link_bw * 1e3          # int8 wire
    t_comb = sent * w.d * 2 / chip.ici_link_bw * 1e3
    t_quant = 2 * w.T * w.d * 2 / chip.hbm_bw * 1e3
    seq_total = t_quant + t_disp + t_comp + t_comb + 4 * KERNEL_LAUNCH * 1e3
    over_total = max(t_disp + t_quant, t_self) + t_remote + t_comb \
        + 4 * KERNEL_LAUNCH * 1e3
    # device-initiated tight dispatch (the DeepEP analogue, one fused launch)
    tight = int(counts.sum() - counts[0])
    t_disp_t = tight * w.d * 1 / chip.ici_link_bw * 1e3
    t_comb_t = tight * w.d * 2 / chip.ici_link_bw * 1e3
    deepep = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
                       "GRID_STEP", "PER_PEER", "ACQUIRE", 2,
                       tunables=(("tight", 1), ("wire_i8", 1)))
    deepep_total = w.analytic_cost(deepep, hw) * 1e6
    # FLUX point: tile-fused expert GEMM, per-tile combine, int8 wire
    flux = EXPERT_SYSTEMS["FLUX"].with_tunable("wire_i8", 1)
    flux_total = w.analytic_cost(flux, hw) * 1e6
    rows = [
        ("table5/quantize_ms", t_quant * 1e3, ""),
        ("table5/dispatch_ms", t_disp * 1e3, "hidden behind self-compute "
         f"({t_self:.3f} ms) in CUCo" if t_self > t_disp else "exposed"),
        ("table5/compute_ms", t_comp * 1e3, f"self={t_self:.3f}ms "
         f"remote={t_remote:.3f}ms"),
        ("table5/combine_ms", t_comb * 1e3, ""),
        ("table5/dispatch_tight_ms", t_disp_t * 1e3,
         f"device-initiated per-peer wire: {tight} vs {sent} tok padded"),
        ("table5/combine_tight_ms", t_comb_t * 1e3, ""),
        ("table5/sequential_total_ms", seq_total * 1e3, "DeepEP-style"),
        ("table5/cuco_total_ms", over_total * 1e3,
         f"delta={(seq_total - over_total) / seq_total * 100:.1f}% "
         "(paper: -12.4%)"),
        ("table5/deepep_kernel_total_ms", deepep_total,
         f"delta={(seq_total - deepep_total / 1e3) / seq_total * 100:.1f}% "
         "vs sequential (tight wire + 1 launch + signal)"),
        ("table5/flux_kernel_total_ms", flux_total,
         f"delta={(seq_total - flux_total / 1e3) / seq_total * 100:.1f}% "
         "vs sequential (tile-fused GEMM + per-tile combine)"),
    ]
    return rows
