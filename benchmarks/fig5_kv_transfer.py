"""Paper Figure 5: KV-cache transfer latency across sequence lengths and KV
dims — host bundled transfer vs CUCo chained GPU-triggered sends."""
from repro.core import Directive, extract_hardware_context
from repro.workloads import get_workload


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    hw = extract_hardware_context(mesh or make_mesh((1,), ("x",)))
    rows = []
    host = Directive("XLA_COLLECTIVE", placement="DEFERRED")
    cuco = Directive("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT")
    for T in (2048, 4096, 8192):
        for dk in (512, 1024):
            w = get_workload("kv_transfer", T=T, d=4096, dk=dk)
            th = w.analytic_cost(host, hw) * 1e3
            tc = w.analytic_cost(cuco, hw) * 1e3
            rows.append((f"fig5/kv_T{T}_dk{dk}_host", th * 1e3, ""))
            rows.append((f"fig5/kv_T{T}_dk{dk}_cuco", tc * 1e3,
                         f"speedup={th / tc:.3f}x"))
    return rows
