"""Shared benchmark utilities: small-shape wall-clock + full-shape modeled
latency for workload variants."""
import time

import jax


def wallclock_us(fn, inputs, iters=3):
    fn(*inputs)                                     # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*inputs))
    return (time.perf_counter() - t0) / iters * 1e6


def modeled_ms(workload, directive, hw):
    return workload.analytic_cost(directive, hw) * 1e3
