"""Shared benchmark utilities: small-shape wall-clock + full-shape modeled
latency for workload variants, and the one JSON table emitter every fig
script writes through (``write_rows``)."""
import json

from repro.core.telemetry import wallclock_us  # noqa: F401  (re-export)


def modeled_ms(workload, directive, hw):
    return workload.analytic_cost(directive, hw) * 1e3


def write_rows(path, rows):
    """Persist one fig script's ``(name, us_per_call, derived)`` rows as a
    ``bench-rows/v1`` JSON table (sorted keys, trailing newline — the same
    diff-stable conventions as BENCH_search.json)."""
    payload = {
        "schema": "bench-rows/v1",
        "rows": [{"name": str(n), "us_per_call": float(us),
                  "derived": str(d)} for n, us, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload
