"""Paper Figure 6: GEMM + AllGather across square matrix sizes, intra-node
(ICI) and inter-node (DCN-rate) links — host all-gather and chunked
STREAM_SPLIT overlap vs the kernelized points: DEFERRED per-peer slab
broadcast and the FLUX-grade TILE_FUSED + COUNTER per-tile broadcast."""
import dataclasses

from repro.core import Directive, extract_hardware_context
from repro.core.design_space import EXPERT_SYSTEMS
from repro.core.hardware import V5E
from repro.workloads import get_workload

POINTS = (
    ("host", Directive("XLA_COLLECTIVE", placement="DEFERRED")),
    ("stream_split", Directive("XLA_COLLECTIVE", placement="STREAM_SPLIT",
                               contexts=2, tunables=(("chunks", 4),))),
    ("deferred", Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                           "KERNEL", "PER_PEER", "RELEASE", 2)),
    ("flux", EXPERT_SYSTEMS["FLUX"].with_tunable("tile_m", 128)),
)


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    hw = extract_hardware_context(mesh or make_mesh((1,), ("x",)))
    hw_inter = dataclasses.replace(
        hw, chip=dataclasses.replace(V5E, ici_link_bw=V5E.dcn_bw))
    rows = []
    for size in (2048, 4096, 8192):
        for link, h in (("ici", hw), ("dcn", hw_inter)):
            w = get_workload("gemm_allgather", n_dev=4, M=size, K=size,
                             N=size)
            costs = {name: w.analytic_cost(d, h) * 1e3 for name, d in POINTS}
            for name, t in costs.items():
                note = "" if name == "host" \
                    else f"speedup={costs['host'] / t:.3f}x"
                rows.append((f"fig6/gemm_ag_{size}_{link}_{name}", t * 1e3,
                             note))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="also write the table as bench-rows/v1 JSON")
    args = ap.parse_args()
    rows = run()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.out:
        from benchmarks.common import write_rows
        write_rows(args.out, rows)
