"""Paper Figure 6: GEMM + AllGather across square matrix sizes, intra-node
(ICI) and inter-node (DCN-rate) links — host all-gather vs CUCo fused
per-tile broadcast."""
import dataclasses

from repro.core import Directive, extract_hardware_context
from repro.core.hardware import V5E
from repro.workloads import get_workload


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    hw = extract_hardware_context(mesh or make_mesh((1,), ("x",)))
    hw_inter = dataclasses.replace(
        hw, chip=dataclasses.replace(V5E, ici_link_bw=V5E.dcn_bw))
    rows = []
    host = Directive("XLA_COLLECTIVE", placement="DEFERRED")
    cuco = Directive("PALLAS_RDMA", "SIGNAL", "TILE_FUSED",
                     granularity="PER_TILE", tunables=(("tile_m", 128),))
    for size in (2048, 4096, 8192):
        for link, h in (("ici", hw), ("dcn", hw_inter)):
            w = get_workload("gemm_allgather", n_dev=4, M=size, K=size,
                             N=size)
            th = w.analytic_cost(host, h) * 1e3
            tc = w.analytic_cost(cuco, h) * 1e3
            rows.append((f"fig6/gemm_ag_{size}_{link}_host", th * 1e3, ""))
            rows.append((f"fig6/gemm_ag_{size}_{link}_cuco", tc * 1e3,
                         f"speedup={th / tc:.3f}x"))
    return rows
