"""Roofline summary per (arch x shape) from the dry-run artifacts — the
benchmark view of EXPERIMENTS.md §Roofline (reads artifacts/dryrun)."""
import json
import pathlib

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run(mesh=None):
    rows = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        d = json.loads(f.read_text())
        if "skipped" in d or "roofline" not in d:
            continue
        r = d["roofline"]
        name = f"roofline/{d['arch']}__{d['shape']}__{d['mesh']}"
        rows.append((name, r["step_time_s"] * 1e6,
                     f"dom={r['dominant']} comp={r['compute_s'] * 1e3:.1f}ms "
                     f"mem={r['memory_s'] * 1e3:.1f}ms "
                     f"coll={r['collective_s'] * 1e3:.1f}ms "
                     f"useful={d['useful_flops_ratio']:.2f}"))
    return rows
