"""Paper Figure 4: DeepSeek-V3 MoE layer across expert skew (2:1..5:1) —
sequential host flow vs CUCo self/remote split (+ int8 wire)."""
from repro.core import Directive, extract_hardware_context
from repro.workloads import get_workload


def run(mesh=None):
    from repro.launch.mesh import make_mesh
    hw = extract_hardware_context(mesh or make_mesh((1,), ("x",)))
    rows = []
    host = Directive("XLA_COLLECTIVE", placement="DEFERRED",
                     granularity="PER_CHUNK")
    cuco = Directive("XLA_COLLECTIVE", placement="STREAM_SPLIT",
                     granularity="PER_PEER", tunables=(("tight", 1),))
    cuco_q = cuco.with_tunable("wire_i8", 1)
    for skew in (2.0, 3.0, 4.0, 5.0):
        w = get_workload("moe_dispatch", n_dev=2, tokens_per_rank=4096,
                         d=7168, f=2048, skew=skew)
        th = w.analytic_cost(host, hw) * 1e3
        tc = w.analytic_cost(cuco, hw) * 1e3
        tq = w.analytic_cost(cuco_q, hw) * 1e3
        rows.append((f"fig4/moe_skew{skew:.0f}_host", th * 1e3, ""))
        rows.append((f"fig4/moe_skew{skew:.0f}_cuco", tc * 1e3,
                     f"speedup={th / tc:.3f}x"))
        rows.append((f"fig4/moe_skew{skew:.0f}_cuco_i8", tq * 1e3,
                     f"speedup={th / tq:.3f}x"))
    return rows
