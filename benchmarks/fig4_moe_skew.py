"""Paper Figure 4: DeepSeek-V3 MoE layer across expert skew (2:1..5:1) —
sequential host flow vs CUCo self/remote split (+ int8 wire) vs the
device-initiated Pallas dispatch/combine kernel. Kernelized rows cover both
realized expert points: DeepEP (tight per-peer wire, per-edge signal,
pipelined peer compute) and FLUX (tile-fused expert GEMM with per-tile
combine writes, COUNTER completion).

Run directly for the CLI: ``python -m benchmarks.fig4_moe_skew --n-dev 8``
sweeps the 8-expert shape (default 2, the paper shape; n_dev=8 is for when
the interpret-mode runtime budget allows the matching executable suite)."""
from repro.core import EXPERT_SYSTEMS, Directive, extract_hardware_context
from repro.workloads import get_workload


def run(mesh=None, n_dev=2):
    from repro.launch.mesh import make_mesh
    hw = extract_hardware_context(mesh or make_mesh((1,), ("x",)))
    rows = []
    host = Directive("XLA_COLLECTIVE", placement="DEFERRED",
                     granularity="PER_CHUNK")
    cuco = Directive("XLA_COLLECTIVE", placement="STREAM_SPLIT",
                     granularity="PER_PEER", tunables=(("tight", 1),))
    cuco_q = cuco.with_tunable("wire_i8", 1)
    # Table-3 DeepEP (NVL) coordinates: device-initiated, per-peer, deferred
    deepep_nvl = Directive("PALLAS_RDMA", "BARRIER", "DEFERRED", "LOCAL",
                           "KERNEL", "PER_PEER", "RELEASE", 1,
                           tunables=(("tight", 1),))
    # the slow-path refinement of that point: signal completion + pipelined
    # per-peer expert compute + double-buffered sends (tight dispatch)
    deepep_pipe = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED",
                            "LOCAL", "GRID_STEP", "PER_PEER", "ACQUIRE", 2,
                            tunables=(("tight", 1),))
    # ablation: same kernel forced onto padded max-capacity blocks
    deepep_padded = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED",
                              "LOCAL", "GRID_STEP", "PER_CHUNK", "ACQUIRE", 2)
    # Table-3 FLUX coordinates: tile-fused expert GEMM, per-tile combine
    # writes, COUNTER completion — plus a slow-path-refined variant
    flux = EXPERT_SYSTEMS["FLUX"]
    flux_tuned = flux.with_tunable("block_tokens", 128)
    for skew in (2.0, 3.0, 4.0, 5.0):
        w = get_workload("moe_dispatch", n_dev=n_dev, tokens_per_rank=4096,
                         d=7168, f=2048, skew=skew)
        th = w.analytic_cost(host, hw) * 1e3
        tc = w.analytic_cost(cuco, hw) * 1e3
        tq = w.analytic_cost(cuco_q, hw) * 1e3
        tn = w.analytic_cost(deepep_nvl, hw) * 1e3
        tp = w.analytic_cost(deepep_pipe, hw) * 1e3
        tpad = w.analytic_cost(deepep_padded, hw) * 1e3
        tf = w.analytic_cost(flux, hw) * 1e3
        tft = w.analytic_cost(flux_tuned, hw) * 1e3
        counts = w._counts(w.T)
        tight_tok = int(counts.sum() - counts[0])
        padded_tok = int(counts.max()) * (w.n_dev - 1)
        rows.append((f"fig4/moe_skew{skew:.0f}_host", th * 1e3, ""))
        rows.append((f"fig4/moe_skew{skew:.0f}_cuco", tc * 1e3,
                     f"speedup={th / tc:.3f}x"))
        rows.append((f"fig4/moe_skew{skew:.0f}_cuco_i8", tq * 1e3,
                     f"speedup={th / tq:.3f}x"))
        rows.append((f"fig4/moe_skew{skew:.0f}_deepep_nvl", tn * 1e3,
                     f"speedup={th / tn:.3f}x"))
        rows.append((f"fig4/moe_skew{skew:.0f}_deepep_tight", tp * 1e3,
                     f"speedup={th / tp:.3f}x wire={tight_tok}tok "
                     f"(padded={padded_tok}tok, "
                     f"{padded_tok / max(1, tight_tok):.2f}x)"))
        rows.append((f"fig4/moe_skew{skew:.0f}_deepep_padded", tpad * 1e3,
                     f"speedup={th / tpad:.3f}x"))
        rows.append((f"fig4/moe_skew{skew:.0f}_flux", tf * 1e3,
                     f"speedup={th / tf:.3f}x tile-fused combine"))
        rows.append((f"fig4/moe_skew{skew:.0f}_flux_tuned", tft * 1e3,
                     f"speedup={th / tft:.3f}x block_tokens=128"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-dev", type=int, default=2,
                    help="expert/rank count for the sweep (paper shape: 2)")
    ap.add_argument("--out", default=None,
                    help="also write the table as bench-rows/v1 JSON")
    args = ap.parse_args()
    rows = run(n_dev=args.n_dev)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.out:
        from benchmarks.common import write_rows
        write_rows(args.out, rows)
