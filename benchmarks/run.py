import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    # benches exercise real 4-rank collectives (the paper's deployment size);
    # NOT the 512-device dry-run flag.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("x",))
    from benchmarks import (fig3_flash_attention, fig4_moe_skew,
                            fig5_kv_transfer, fig6_gemm_allgather,
                            table5_moe_phases, fig9_13_ablations,
                            roofline_cells)
    modules = [fig3_flash_attention, fig4_moe_skew, fig5_kv_transfer,
               fig6_gemm_allgather, table5_moe_phases, fig9_13_ablations,
               roofline_cells]
    print("name,us_per_call,derived")
    failures = 0
    for m in modules:
        try:
            for name, us, derived in m.run(mesh):
                print(f"{name},{us:.3f},{derived}")
        except Exception:
            failures += 1
            print(f"{m.__name__},ERROR,", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
