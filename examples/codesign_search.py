"""Run the CUCo co-design pipeline on a workload: static analysis ->
fast-path verified seed -> slow-path evolutionary search; prints the
communication graph, the discovered directive and the modeled speedup.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/codesign_search.py --workload moe_dispatch
"""
import argparse

from repro.core import (SlowPathConfig, extract_hardware_context, fast_path,
                        slow_path)
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="moe_dispatch",
                    choices=["ring_attention", "moe_dispatch", "kv_transfer",
                             "gemm_allgather"])
    ap.add_argument("--generations", type=int, default=10)
    ap.add_argument("--islands", type=int, default=3)
    args = ap.parse_args()

    n = len(jax.devices())
    mesh = make_mesh((min(n, 4),), ("x",)) if args.workload != "kv_transfer" \
        else make_mesh((min(n, 2),), ("x",))
    hw = extract_hardware_context(mesh)
    print(hw.topology_summary)

    kw = {}
    if args.workload in ("ring_attention", "moe_dispatch", "gemm_allgather"):
        kw["n_dev"] = mesh.shape["x"]
    w = get_workload(args.workload, **kw)

    print("\n=== fast path (correctness-first) ===")
    seed = fast_path(w, mesh, hw, verbose=True)
    for line in seed.log:
        print(" ", line)
    print("seed directive:\n" + seed.directive.render())

    print("\n=== slow path (evolutionary search) ===")
    res = slow_path(seed, mesh, hw,
                    SlowPathConfig(islands=args.islands,
                                   generations=args.generations),
                    verbose=True)
    print("\ndiscovered:\n" + res.best.directive.render())
    t_seed = 10000.0 / res.seed_score - 1.0
    t_best = 10000.0 / res.best.score - 1.0
    print(f"\nmodeled step: {t_seed:.3f} ms (seed) -> {t_best:.3f} ms "
          f"({t_seed / t_best:.2f}x); behaviors explored: "
          f"{res.archive.coverage()}")
    print("meta-summarizer digests:", res.meta.digests[-1]
          if res.meta.digests else "(none)")


if __name__ == "__main__":
    main()
