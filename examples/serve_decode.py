"""Serve a small model with batched requests + disaggregated prefill/decode.

    PYTHONPATH=src python examples/serve_decode.py --arch llama3.2-1b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import init_params
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params,
                 ServeConfig(max_seq=args.prompt_len + args.new_tokens + 1))

    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len))
             .astype(np.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = np.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                                   np.float32)
    if cfg.num_patch_tokens:
        batch["patches"] = np.zeros(
            (args.batch, cfg.num_patch_tokens, cfg.d_model), np.float32)

    t0 = time.perf_counter()
    toks = eng.generate(batch, args.new_tokens)
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"monolithic: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")

    # disaggregated: prefill tier -> cache handoff -> decode tier
    handoff = eng.prefill_remote(batch)
    toks2 = eng.decode_from_handoff(handoff, args.new_tokens)
    same = np.array_equal(np.asarray(toks), np.asarray(toks2))
    print(f"disaggregated prefill/decode equals monolithic: {same}")
    print("sample output ids:", np.asarray(toks[0][:12]))


if __name__ == "__main__":
    main()
