"""Quickstart: build an assigned architecture, train a few steps, serve it.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3.2-1b]
"""
import argparse

import numpy as np

from repro.configs import get_arch, reduced
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))     # smoke-sized config, same family
    print(f"arch={cfg.name} family={cfg.family} "
          f"pattern={cfg.block_pattern[:4]}...")

    tcfg = TrainConfig(steps=args.steps, global_batch=8, seq_len=64,
                       log_every=10)
    losses, _, (params, _) = train(cfg, tcfg)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    eng = Engine(cfg, params, ServeConfig(max_seq=96))
    batch = {"tokens": np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = np.zeros((2, cfg.enc_seq, cfg.d_model), np.float32)
    if cfg.num_patch_tokens:
        batch["patches"] = np.zeros((2, cfg.num_patch_tokens, cfg.d_model),
                                    np.float32)
    toks = eng.generate(batch, 8)
    print("generated token ids:\n", np.asarray(toks))


if __name__ == "__main__":
    main()
