"""End-to-end driver: train a ~100M-parameter MoE LM for a few hundred steps
with sharded execution, checkpointing, preemption-safe restart, and the CUCo
MoE overlap schedule enabled.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_moe_100m.py --steps 300

(On one CPU device it runs unsharded; with the flag it runs 4-way data x
2-way model parallel.)
"""
import argparse

import jax

from repro.configs import get_arch, reduced
from repro.models import StepOptions
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_moe_100m")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: granite-moe family scaled between smoke and full size
    cfg = reduced(
        get_arch("granite-moe-3b-a800m"),
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1024, moe_d_ff=1024, num_experts=8, experts_per_token=2,
        vocab_size=32000, pad_to=2, name="granite-moe-100m")
    n_est = cfg.param_count()
    print(f"model: {cfg.name}, ~{n_est / 1e6:.0f}M params (analytic)")

    mesh = None
    if len(jax.devices()) >= 8:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        print("mesh:", dict(mesh.shape))

    tcfg = TrainConfig(
        steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt, ckpt_every=100, log_every=20,
        opts=StepOptions(moe_overlap=True))      # CUCo self/remote split
    losses, last, _ = train(cfg, tcfg, mesh=mesh)
    print(f"trained to step {last}; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"checkpoints in {args.ckpt} — re-run to resume, SIGTERM to "
          "preempt gracefully")


if __name__ == "__main__":
    main()
