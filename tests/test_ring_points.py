"""Ring-workload expert-point validity + ring-rotation schedule cost
accounting (the analog of test_collective_points.py for the two ring
workloads: ring_attention and kv_transfer).

These run without hypothesis and without simulated devices (the 1-rank
cascade smoke uses the default 1-device jax): directive validity and the l3
analytic model are pure functions. The executable 4-rank interpret-mode
counterparts live in tests/scripts/ring_kernel_suite.py.
"""
import dataclasses


from repro.core.cost_model import per_tile_exposed_s, window_stall_factor
from repro.core.design_space import EXPERT_SYSTEMS, TUNABLES, Directive
from repro.core.hardware import V5E, HardwareContext
from repro.workloads import get_workload

HW = HardwareContext(chip=V5E, mesh_shape=(4,), mesh_axes=("x",),
                     chips_per_pod=4, n_chips=4, has_dcn=False)

FLUX = EXPERT_SYSTEMS["FLUX"]
HOST = Directive("XLA_COLLECTIVE", placement="DEFERRED")
PIPELINED = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
                      "GRID_STEP", "PER_TILE", "ACQUIRE", 2)
DEFERRED_KERNEL = Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                            "KERNEL", "PER_PEER", "RELEASE", 2)


def ring(**kw):
    kw.setdefault("n_dev", 4)
    kw.setdefault("BH", 96)
    kw.setdefault("seq", 4096)
    kw.setdefault("hd", 64)
    return get_workload("ring_attention", **kw)


def kvt(**kw):
    return get_workload("kv_transfer", **kw)


def test_ring_workloads_are_kernelizable():
    assert ring().kernelizable and ring().traits(HW)["ring_topology"]
    assert kvt().kernelizable and not kvt().traits(HW)["ring_topology"]


def test_expert_points_valid_for_ring_workloads():
    """Every Table-3 expert directive validates under both ring-workload
    traits — in particular FLUX (TILE_FUSED + COUNTER + PER_TILE), the
    point the chunk-rotating kernels realize."""
    for w in (ring(), kvt()):
        for name, d in EXPERT_SYSTEMS.items():
            v = w.check(d, HW)
            assert not v, (w.name, name, v)
        assert not w.check(DEFERRED_KERNEL, HW)
    # the ring-topology bound still rejects PER_PEER fused exchanges
    bad = dataclasses.replace(FLUX, granularity="PER_PEER")
    assert ring().check(bad, HW)


# --------------------------------------------------- ring-rotation schedule

def test_ring_schedule_shapes():
    from repro.core.schedule import make_ring_schedule

    fused = make_ring_schedule(4, 1024, 64, fused=True)
    assert fused.steps == 3 and fused.nc == 16
    assert fused.issued_rounds() == 3 * 16
    assert fused.rows_per_round == 64
    slab = make_ring_schedule(4, 1024, 64, fused=False)
    assert slab.issued_rounds() == 3
    assert slab.rows_per_round == 1024
    # the schedule changes when rows move, never how many
    assert fused.wire_rows() == slab.wire_rows() == 3 * 1024
    # the chunk-rotating kernels wait per-chunk semaphores whether the
    # ticks are interleaved (COUNTER) or drained up front (SIGNAL), so
    # both charge one tick per (step, chunk) event; the whole-shard
    # DEFERRED/PIPELINED path waits once per step
    assert fused.completion_ticks(counter=True) == 3 * 16
    assert fused.completion_ticks(counter=False) == 3 * 16
    assert slab.completion_ticks(counter=False) == 3
    # ring send windows drain at step boundaries: the depth mirror resets
    # per step instead of carrying across the credit handshake
    assert max(fused.send_window_depths(4)) == 4
    assert fused.send_window_depths(4)[16] == 1        # step 1 starts fresh
    # kv_shuttle's degenerate 2-rank ring: one step, chunk-major
    shuttle = make_ring_schedule(2, 4096, 64, fused=True)
    assert shuttle.steps == 1 and shuttle.issued_rounds() == 64


def test_per_chunk_overlap_credit_monotone():
    """The per-chunk rotation credit (cost_model.per_tile_exposed_s): the
    exposed tail shrinks monotonically as the chunk count grows — finer
    chunks leave less of each rotation step on the critical path."""
    wire = 2 * 96 * 1024 * 64 * 2
    exposed = [per_tile_exposed_s(wire, V5E.ici_link_bw, t)
               for t in (1, 4, 16, 64)]
    assert all(a > b for a, b in zip(exposed, exposed[1:]))
    # and the workload model consumes it: finer kv_chunk -> smaller
    # exposed tail but more TILE_SYNC ticks, so the knob has a real
    # optimum, not a monotone best
    w = ring()
    coarse = w.analytic_cost(FLUX.with_tunable("kv_chunk", 256), HW)
    fine = w.analytic_cost(FLUX.with_tunable("kv_chunk", 16), HW)
    assert coarse != fine
    # the recycle stall shrinks with a deeper window (shared helper)
    assert window_stall_factor(4) < window_stall_factor(1)


def test_flux_ring_beats_pipelined_deferred_and_host():
    """At the paper deployment shape (wire-bound ring) the chunk-rotating
    FLUX point beats the lazy-fence pipelined point, the DEFERRED kernel,
    and the host baseline; a deeper send window shrinks the per-chunk
    recycle stall."""
    w = ring()
    host = w.analytic_cost(HOST, HW)
    pipe = w.analytic_cost(PIPELINED, HW)
    deferred = w.analytic_cost(DEFERRED_KERNEL, HW)
    flux = w.analytic_cost(FLUX, HW)
    assert flux < pipe < host
    assert flux < deferred < host
    deeper = dataclasses.replace(FLUX, contexts=2)
    assert w.analytic_cost(deeper, HW) < flux


def test_flux_shuttle_beats_chained_and_host():
    """kv_transfer: the per-tile fused K/V chain (FLUX) beats the chained
    point, which beats the bundled host transfer; the `chained` tunable
    flips the non-fused kernel back to the sequential shape."""
    w = kvt()
    host = w.analytic_cost(HOST, HW)
    chained = w.analytic_cost(
        Directive("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT"), HW)
    flux = w.analytic_cost(FLUX, HW)
    assert flux < chained < host
    unchained = w.analytic_cost(
        Directive("PALLAS_RDMA", "SIGNAL",
                  "STREAM_SPLIT").with_tunable("chained", 0), HW)
    assert unchained > chained
    deeper = dataclasses.replace(FLUX, contexts=2)
    assert w.analytic_cost(deeper, HW) < flux


def test_build_and_cost_share_knob_mapping():
    """kernel_knobs (the Workload protocol's search contract) is the single
    directive->knob mapping: BARRIER forces the whole-shard drain even
    under TILE_FUSED, COUNTER marks per-chunk ticks, ACQREL orders the
    non-fused fence eagerly, and the `chained` tunable overrides the
    placement-derived chain."""
    w = ring()
    k = w.kernel_knobs(FLUX)
    assert k["fused"] and k["counter"] and k["kv_chunk"] == 64
    barrier = dataclasses.replace(FLUX, completion="BARRIER")
    assert not w.kernel_knobs(barrier)["fused"]
    # BARRIER's global-rendezvous semantics force the serialized drain
    # even under a pipelined placement (eager fence, no overlap credit)
    assert w.kernel_knobs(
        dataclasses.replace(PIPELINED, completion="BARRIER"))["eager"]
    assert w.kernel_knobs(PIPELINED)["pipelined"]
    assert not w.kernel_knobs(PIPELINED)["eager"]
    eager = dataclasses.replace(PIPELINED, ordering="ACQREL")
    assert w.kernel_knobs(eager)["eager"]

    wk = kvt()
    assert wk.kernel_knobs(FLUX)["fused"]
    chained = Directive("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT")
    assert wk.kernel_knobs(chained)["chained"]
    assert not wk.kernel_knobs(chained.with_tunable("chained", 0))["chained"]
    assert not wk.kernel_knobs(
        dataclasses.replace(chained, ordering="ACQREL"))["chained"]
    assert not wk.kernel_knobs(
        dataclasses.replace(chained, completion="BARRIER"))["chained"]
    # fast_path seeds directives with default_tunables: the stored
    # ("chained", None) placeholder means "unset" and must not shadow the
    # placement-derived default
    seeded = dataclasses.replace(
        chained, tunables=tuple(sorted(wk.default_tunables().items())))
    assert seeded.tunable("chained", True) is None     # the trap itself
    assert wk.kernel_knobs(seeded)["chained"]


# ----------------------------------------------------- kv_chunk sanitization

def test_kv_chunk_sanitized_to_divisor():
    """A slow-path diff patch may propose any TUNABLES grid value (and
    worse); every request must map to a divisor of the KV shard so the
    kernel contract's ``rows % kv_chunk == 0`` can never crash the
    evaluator."""
    from repro.core.schedule import sanitize_kv_chunk

    for rows in (64, 96, 128, 192, 1024):
        for req in list(TUNABLES["kv_chunk"]) + [1, 7, 48, 100, 10_000]:
            kc = sanitize_kv_chunk(req, rows)
            assert rows % kc == 0, (req, rows, kc)
            assert 1 <= kc <= rows
    # exact divisors pass through untouched
    assert sanitize_kv_chunk(64, 1024) == 64
    assert sanitize_kv_chunk(None, 512) == 512


def test_non_divisor_kv_chunk_does_not_crash_evaluator():
    """The cascade survives (and scores) a FLUX-ring directive whose
    kv_chunk does not divide the example-input shard."""
    from repro.core.cascade import Candidate, CascadeEvaluator
    from repro.core.hardware import extract_hardware_context
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("x",))
    w = ring(n_dev=1, BH=2, seq=128)
    ev = CascadeEvaluator(w, mesh, extract_hardware_context(mesh))
    for bad in (48, 100, 7):
        res = ev.evaluate(Candidate(directive=FLUX.with_tunable("kv_chunk",
                                                                bad)))
        assert res.level == 3, (bad, res.diagnostic)


# ------------------------------------------------ slow-path tunable space

def test_ring_knobs_in_slow_path_search_space():
    """kv_chunk / contexts (ring_attention) and chained / kv_chunk
    (kv_transfer) are refinable diff-patch dimensions drawn from the
    central TUNABLES registry."""
    import random

    from repro.core.cascade import Candidate, EvalResult
    from repro.core.mutation import HeuristicMutator, MutationContext
    from repro.core.slow_path import _tunable_space

    space = _tunable_space(ring())
    assert space["kv_chunk"] == TUNABLES["kv_chunk"]
    assert "contexts" in space
    kspace = _tunable_space(kvt())
    assert kspace["chained"] == TUNABLES["chained"]
    assert kspace["kv_chunk"] == TUNABLES["kv_chunk"]

    traits = ring().traits(HW)
    parent = Candidate(directive=FLUX)
    parent.result = EvalResult(3, 100.0, 1.0, diagnostic="ok: modeled")
    ctx = MutationContext(parent=parent, phase="exploit", traits=traits,
                          tunable_space=space)
    mut = HeuristicMutator()
    moved = set()
    for seed in range(400):
        rng = random.Random(seed)
        child, _ = mut.propose(ctx, rng)
        if child.contexts != parent.directive.contexts:
            moved.add("contexts")
        if child.tunable("kv_chunk") != parent.directive.tunable("kv_chunk"):
            moved.add("kv_chunk")
    assert {"kv_chunk", "contexts"} <= moved, moved


# --------------------------------------------------------- l3 cascade smoke

def test_flux_ring_cascade_reaches_l3():
    """The FLUX directive builds, verifies under interpret mode, and
    scores at l3 through the full cascade for the ring workload (1-rank
    mesh; the 4-rank version runs in tests/scripts/ring_kernel_suite.py)."""
    from repro.core.cascade import Candidate, CascadeEvaluator
    from repro.core.hardware import extract_hardware_context
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("x",))
    w = ring(n_dev=1, BH=2, seq=128)
    ev = CascadeEvaluator(w, mesh, extract_hardware_context(mesh))
    for d in (FLUX, DEFERRED_KERNEL):
        res = ev.evaluate(Candidate(directive=d))
        assert res.level == 3, res.diagnostic
        assert res.score > 0


def test_fig3_reports_kernelized_rows():
    from benchmarks import fig3_flash_attention

    rows = fig3_flash_attention.run()
    names = [r[0] for r in rows]
    for seq in (4096, 8192):
        for hd in (32, 64):
            for point in ("host", "cuco", "deferred", "flux"):
                assert f"fig3/ring_attn_seq{seq}_hd{hd}_{point}" in names
    host = next(r for r in rows if r[0] == "fig3/ring_attn_seq4096_hd64_host")
    flux = next(r for r in rows if r[0] == "fig3/ring_attn_seq4096_hd64_flux")
    deferred = next(r for r in rows
                    if r[0] == "fig3/ring_attn_seq4096_hd64_deferred")
    assert flux[1] < deferred[1] < host[1]
