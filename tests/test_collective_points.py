"""gemm_allgather expert-point validity + broadcast-schedule cost accounting
(the analog of test_expert_points.py for the second kernelized workload).

These run without hypothesis and without simulated devices (the 1-rank
cascade smoke uses the default 1-device jax): directive validity and the l3
analytic model are pure functions. The executable 4-rank interpret-mode
counterparts live in tests/scripts/collective_kernels_suite.py.
"""
import dataclasses


from repro.core.cost_model import per_tile_exposed_s
from repro.core.design_space import EXPERT_SYSTEMS, TUNABLES, Directive
from repro.core.hardware import V5E, HardwareContext
from repro.workloads import get_workload

HW = HardwareContext(chip=V5E, mesh_shape=(4,), mesh_axes=("x",),
                     chips_per_pod=4, n_chips=4, has_dcn=False)

FLUX = EXPERT_SYSTEMS["FLUX"]
HOST = Directive("XLA_COLLECTIVE", placement="DEFERRED")
DEFERRED_KERNEL = Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                            "KERNEL", "PER_PEER", "RELEASE", 2)


def ga(**kw):
    kw.setdefault("n_dev", 4)
    kw.setdefault("M", 4096)
    kw.setdefault("K", 4096)
    kw.setdefault("N", 4096)
    return get_workload("gemm_allgather", **kw)


def test_gemm_allgather_is_kernelizable():
    w = ga()
    assert w.kernelizable
    assert w.traits(HW)["kernelizable"]


def test_expert_points_valid_for_gemm_allgather():
    """Every Table-3 expert directive validates under the gemm_allgather
    traits — in particular FLUX (TILE_FUSED + COUNTER), the point the
    kernel realizes per-tile."""
    w = ga()
    for name, d in EXPERT_SYSTEMS.items():
        v = w.check(d, HW)
        assert not v, (name, v)
    assert not w.check(DEFERRED_KERNEL, HW)


# ------------------------------------------------- broadcast-round schedule

def test_broadcast_schedule_shapes():
    from repro.kernels.gemm_allgather import make_broadcast_schedule

    fused = make_broadcast_schedule(4, 1024, 128, fused=True)
    assert fused.nt == 8
    assert fused.issued_rounds() == 3 * 8
    assert fused.rows_per_round == 128
    slab = make_broadcast_schedule(4, 1024, 128, fused=False)
    assert slab.issued_rounds() == 3
    assert slab.rows_per_round == 1024
    # the schedule changes when rows move, never how many
    assert fused.wire_rows() == slab.wire_rows() == 3 * 1024
    # COUNTER ticks per (src, tile) edge; SIGNAL/DEFERRED per edge
    assert fused.completion_ticks(counter=True) == 3 * 8
    assert fused.completion_ticks(counter=False) == 3
    assert slab.completion_ticks(counter=False) == 3


def test_per_tile_overlap_credit_monotone():
    """The per-tile broadcast credit (cost_model.per_tile_exposed_s): the
    exposed tail shrinks monotonically as the tick count grows — finer
    tiles leave less of the final transfer on the critical path."""
    wire = 3 * 1024 * 4096 * 2
    exposed = [per_tile_exposed_s(wire, V5E.ici_link_bw, t)
               for t in (1, 3, 8, 24, 96)]
    assert all(a > b for a, b in zip(exposed, exposed[1:]))
    # and the workload model consumes it: more tiles -> smaller exposed
    # tail but more TILE_SYNC ticks, so the knob has a real optimum
    w = ga()
    coarse = w.analytic_cost(FLUX.with_tunable("tile_m", 128), HW)
    fine = w.analytic_cost(FLUX.with_tunable("tile_m", 32), HW)
    assert coarse != fine


def test_flux_point_beats_host_and_deferred():
    """At the paper shape the fused per-tile broadcast beats both the host
    all-gather and the kernelized DEFERRED slab path; a deeper send window
    shrinks the per-tile recycle stall."""
    w = ga()
    host = w.analytic_cost(HOST, HW)
    deferred = w.analytic_cost(DEFERRED_KERNEL, HW)
    flux = w.analytic_cost(FLUX, HW)
    assert flux < deferred < host
    deeper = dataclasses.replace(FLUX, contexts=2)
    assert w.analytic_cost(deeper, HW) < flux


def test_build_and_cost_share_knob_mapping():
    """kernel_knobs (the Workload protocol's search contract) is the
    single directive->knob mapping: BARRIER forces the deferred drain even
    under TILE_FUSED, COUNTER marks per-tile ticks, and tile_m is
    sanitized to a divisor of the local slab (the deployment slab when no
    shape is passed)."""
    w = ga()
    k = w.kernel_knobs(FLUX, 1024)
    assert k["tile_m"] == 128 and k["fused"] and k["counter"]
    assert k["contexts"] == FLUX.contexts
    assert w.kernel_knobs(FLUX)["tile_m"] == 128       # M = 4096, n = 4
    barrier = dataclasses.replace(FLUX, completion="BARRIER")
    assert not w.kernel_knobs(barrier, 1024)["fused"]
    assert w.kernel_knobs(FLUX.with_tunable("tile_m", 96), 128)["tile_m"] \
        == 64


# ------------------------------------------------------ tile_m sanitization

def test_tile_m_sanitized_to_divisor():
    """ISSUE-4 satellite fix: an unsanitized tile_m used to hit the
    kernel's ``assert M_l % tm == 0`` — a slow-path mutation could crash
    the evaluator. Every grid value (and worse) must map to a divisor."""
    from repro.kernels.gemm_allgather import sanitize_tile_m

    for M_l in (64, 96, 128, 192, 1024):
        for req in list(TUNABLES["tile_m"]) + [1, 7, 96, 100, 10_000]:
            tm = sanitize_tile_m(req, M_l)
            assert M_l % tm == 0, (req, M_l, tm)
            assert 1 <= tm <= M_l
    # exact divisors pass through untouched
    assert sanitize_tile_m(128, 1024) == 128
    assert sanitize_tile_m(None, 512) == 512


def test_non_divisor_tile_m_does_not_crash_evaluator():
    """The cascade survives (and scores) a directive whose tile_m does not
    divide the example-input slab."""
    from repro.core.cascade import Candidate, CascadeEvaluator
    from repro.core.hardware import extract_hardware_context
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("x",))
    w = ga(n_dev=1)
    ev = CascadeEvaluator(w, mesh, extract_hardware_context(mesh))
    for bad in (96, 100, 7):
        res = ev.evaluate(Candidate(directive=FLUX.with_tunable("tile_m",
                                                                bad)))
        assert res.level == 3, (bad, res.diagnostic)


# ------------------------------------------------ slow-path tunable space

def test_tile_m_in_slow_path_search_space():
    """tile_m is a refinable diff-patch dimension for the kernelized
    gemm_allgather points, drawn from the central TUNABLES registry."""
    import random

    from repro.core.cascade import Candidate, EvalResult
    from repro.core.mutation import HeuristicMutator, MutationContext
    from repro.core.slow_path import _tunable_space

    space = _tunable_space(ga())
    assert space["tile_m"] == TUNABLES["tile_m"]
    assert "contexts" in space and "chunks" in space

    traits = ga().traits(HW)
    parent = Candidate(directive=FLUX)
    parent.result = EvalResult(3, 100.0, 1.0, diagnostic="ok: modeled")
    ctx = MutationContext(parent=parent, phase="exploit", traits=traits,
                          tunable_space=space)
    mut = HeuristicMutator()
    moved = set()
    for seed in range(400):
        rng = random.Random(seed)
        child, _ = mut.propose(ctx, rng)
        if child.contexts != parent.directive.contexts:
            moved.add("contexts")
        if child.tunable("tile_m") != parent.directive.tunable("tile_m"):
            moved.add("tile_m")
    assert {"tile_m", "contexts"} <= moved, moved


# --------------------------------------------------------- l3 cascade smoke

def test_flux_gemm_allgather_cascade_reaches_l3():
    """The FLUX directive builds, verifies under interpret mode, and
    scores at l3 through the full cascade (1-rank mesh; the 4-rank version
    runs in tests/scripts/collective_kernels_suite.py)."""
    from repro.core.cascade import Candidate, CascadeEvaluator
    from repro.core.hardware import extract_hardware_context
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("x",))
    w = ga(n_dev=1)
    ev = CascadeEvaluator(w, mesh, extract_hardware_context(mesh))
    for d in (FLUX, DEFERRED_KERNEL):
        res = ev.evaluate(Candidate(directive=d))
        assert res.level == 3, res.diagnostic
        assert res.score > 0


def test_fig6_reports_kernelized_rows():
    from benchmarks import fig6_gemm_allgather

    rows = fig6_gemm_allgather.run()
    names = [r[0] for r in rows]
    for size in (2048, 4096, 8192):
        for point in ("host", "stream_split", "deferred", "flux"):
            assert f"fig6/gemm_ag_{size}_ici_{point}" in names
    host = next(r for r in rows if r[0] == "fig6/gemm_ag_4096_ici_host")
    flux = next(r for r in rows if r[0] == "fig6/gemm_ag_4096_ici_flux")
    deferred = next(r for r in rows
                    if r[0] == "fig6/gemm_ag_4096_ici_deferred")
    assert flux[1] < deferred[1] < host[1]
