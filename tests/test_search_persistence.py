"""Warm-start persistence round-trip properties (docs/search.md).

Property (hypothesis, skipped if unavailable — mirroring
tests/test_schedules.py): for any small search configuration, save → load
→ resume re-evaluates **zero** directives the store already scored (the
fingerprint-scoped cache serves them), resumed archive coverage is at
least the saved coverage, and a corrupted or version-mismatched store
degrades to a clean cold start. Plus direct store round-trips for
``CandidateDB`` and ``MapElitesArchive`` and their ``StoreError``
surfaces.
"""
import json

import pytest

from repro.core import (CandidateDB, MapElitesArchive, SlowPathConfig,
                        StoreError, directive_key, extract_hardware_context,
                        fast_path, slow_path)
from repro.core.cascade import CascadeEvaluator
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload

# property tests need hypothesis (optional test dep, like
# tests/test_schedules.py): the property skips, the direct store tests run.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def rig():
    wl = get_workload("gemm_allgather", n_dev=1, M=256, K=256, N=256)
    mesh = make_mesh((1,), ("x",))
    hw = extract_hardware_context(mesh)
    seed = fast_path(wl, mesh, hw)
    return wl, mesh, hw, seed


class _CountingEvaluator(CascadeEvaluator):
    """Records the directive key of every evaluation that actually runs
    the cascade (cache hits bypass the evaluator entirely)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.evaluated = []

    def _evaluate(self, cand, publish=True):
        self.evaluated.append(directive_key(cand.directive))
        return super()._evaluate(cand, publish=publish)


def _warm_start_round_trip(rig, tmp, run_seed, islands, generations):
    wl, mesh, hw, seed = rig
    store = str(tmp / "db.json")
    cfg = SlowPathConfig(islands=islands, generations=generations,
                         seed=run_seed)
    cold = slow_path(seed, mesh, hw, cfg, save_to=store)
    saved_keys = {directive_key(r.directive) for r in cold.db.records
                  if r.result is not None}

    ev = _CountingEvaluator(wl, mesh, hw)
    warm = slow_path(seed, mesh, hw, cfg, evaluator=ev, warm_start=store)

    # zero cached directives re-evaluated: every cascade run in the warm
    # search was for a directive the store had never scored
    assert not (set(ev.evaluated) & saved_keys)
    cached = [r for r in warm.db.records if r.cached]
    assert warm.telemetry.scale["warm_start"] is True
    assert warm.telemetry.scale["cache_hits"] == len(cached) > 0
    assert all(directive_key(r.directive) in saved_keys for r in cached)

    # resumed coverage can only grow
    assert warm.archive.coverage() >= cold.archive.coverage()


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(run_seed=st.integers(0, 5), islands=st.integers(2, 3),
           generations=st.integers(1, 2))
    def test_warm_start_round_trip_property(rig, tmp_path_factory, run_seed,
                                            islands, generations):
        _warm_start_round_trip(rig, tmp_path_factory.mktemp("store"),
                               run_seed, islands, generations)
else:
    def test_warm_start_round_trip_property(rig, tmp_path):
        """Hypothesis unavailable: run the property once at a fixed point
        so the round-trip invariant is still exercised in tier-1."""
        _warm_start_round_trip(rig, tmp_path, 2, 2, 2)


def test_corrupt_and_version_mismatch_store_cold_start(rig, tmp_path):
    wl, mesh, hw, seed = rig
    cfg = SlowPathConfig(islands=2, generations=1, seed=0)
    store = str(tmp_path / "db.json")
    cold = slow_path(seed, mesh, hw, cfg, save_to=store)

    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{definitely not json")
    mismatch = tmp_path / "mismatch.json"
    payload = json.loads((tmp_path / "db.json").read_text())
    payload["version"] = 999
    mismatch.write_text(json.dumps(payload))

    for bad in (str(corrupt), str(mismatch), str(tmp_path / "missing.json")):
        run = slow_path(seed, mesh, hw, cfg, warm_start=bad)
        assert run.telemetry.scale == {"warm_start": False, "cache_hits": 0,
                                       "transferred_seeds": 0}
        assert run.history == cold.history     # bit-identical cold search

    with pytest.raises(StoreError):
        CandidateDB.load(str(corrupt))
    with pytest.raises(StoreError):
        CandidateDB.load(str(mismatch))
    with pytest.raises(StoreError):
        MapElitesArchive.load(store)           # wrong store kind


def test_db_and_archive_store_round_trip(rig, tmp_path):
    wl, mesh, hw, seed = rig
    cfg = SlowPathConfig(islands=2, generations=2, seed=1)
    res = slow_path(seed, mesh, hw, cfg)
    wl_fp, hw_fp = wl.fingerprint(), hw.fingerprint

    dbp = str(tmp_path / "db.json")
    res.db.save(dbp, workload=wl_fp, hardware=hw_fp)
    db2 = CandidateDB.load(dbp)
    assert db2.saved_meta == {"workload": wl_fp, "hardware": hw_fp}
    assert db2.history() == res.db.history()
    assert [directive_key(r.directive) for r in db2.records] \
        == [directive_key(r.directive) for r in res.db.records]
    assert [(r.result.level, r.result.score, r.result.retries)
            for r in db2.records] \
        == [(r.result.level, r.result.score, r.result.retries)
            for r in res.db.records]
    # the novelty index came back with the records
    for r in res.db.records:
        assert not db2.is_novel(r.directive)

    arcp = str(tmp_path / "archive.json")
    res.archive.save(arcp, workload=wl_fp, hardware=hw_fp)
    arc2 = MapElitesArchive.load(arcp)
    assert arc2.saved_meta == {"workload": wl_fp, "hardware": hw_fp}
    assert set(arc2.cells) == set(res.archive.cells)
    for b, cand in arc2.cells.items():
        assert cand.score == res.archive.cells[b].score

    # saving is deterministic byte-for-byte
    dbp2 = str(tmp_path / "db2.json")
    db2.save(dbp2, workload=wl_fp, hardware=hw_fp)
    assert open(dbp).read() == open(dbp2).read()
