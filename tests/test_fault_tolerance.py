"""Fault tolerance: watchdog, preemption guard, kill+resume equivalence."""
import os
import signal

import numpy as np

from repro.train.fault_tolerance import PreemptionGuard, StragglerWatchdog


def test_watchdog_flags_persistent_straggler():
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4)
    for _ in range(8):
        assert not w.record(1.0)
    assert w.record(5.0)
    assert w.record(5.0)
    assert w.record(5.0)
    assert w.should_replace


def test_watchdog_tolerates_jitter():
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4)
    rng = np.random.default_rng(0)
    flags = [w.record(1.0 + 0.2 * rng.random()) for _ in range(32)]
    assert not any(flags)
    assert not w.should_replace


def test_preemption_guard_catches_sigterm():
    with PreemptionGuard() as g:
        assert not g.requested
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.requested
    # handler restored after exit
    assert signal.getsignal(signal.SIGTERM) != g._handler


def test_kill_resume_loss_equivalence(tmp_path):
    """A preempted+resumed run reproduces the uninterrupted loss curve."""
    import jax
    from repro.configs import get_arch, reduced
    from repro.train import TrainConfig, train

    cfg = reduced(get_arch("llama3.2-1b"))
    t_int = TrainConfig(steps=12, global_batch=4, seq_len=32,
                        ckpt_dir=str(tmp_path / "a"), ckpt_every=6,
                        log_every=100)
    la, _, _ = train(cfg, t_int, verbose=False, max_steps_this_run=6)
    lb, _, _ = train(cfg, t_int, verbose=False)         # resumes at 6
    t_full = TrainConfig(steps=12, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path / "b"), ckpt_every=100,
                         log_every=100)
    lf, _, _ = train(cfg, t_full, verbose=False)
    np.testing.assert_allclose(la + lb, lf, atol=1e-5)
