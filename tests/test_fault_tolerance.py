"""Fault tolerance: watchdog (incident-window decay, tick normalization),
elastic controller -> degraded schedules, preemption guard, kill+resume
equivalence."""
import os
import signal

import numpy as np
import pytest

from repro.train.fault_tolerance import (ElasticController, PreemptionGuard,
                                         StragglerWatchdog)


def test_watchdog_flags_persistent_straggler():
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4)
    for _ in range(8):
        assert not w.record(1.0)
    assert w.record(5.0)
    assert w.record(5.0)
    assert w.record(5.0)
    assert w.should_replace


def test_watchdog_tolerates_jitter():
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4)
    rng = np.random.default_rng(0)
    flags = [w.record(1.0 + 0.2 * rng.random()) for _ in range(32)]
    assert not any(flags)
    assert not w.should_replace


def test_watchdog_incidents_decay_instead_of_latching():
    """Three blips spread over a long healthy run never arm the trigger
    (the old monotonic counter latched forever), and an armed trigger
    decays back to healthy once the blips age out of the window."""
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4,
                          incident_window=8, replace_after=3)
    for _ in range(8):
        w.record(1.0)
    for _ in range(3):            # blips 10 healthy steps apart
        assert w.record(5.0)
        assert not w.should_replace
        for _ in range(10):
            w.record(1.0)
    assert w.incidents == 3       # lifetime total still counts
    assert w.recent_incidents == 0

    # consecutive blips DO arm it — and then decay clears it again
    assert w.record(5.0) and w.record(5.0) and w.record(5.0)
    assert w.should_replace
    for _ in range(w.incident_window):
        w.record(1.0)
    assert not w.should_replace


def test_watchdog_normalizes_round_ticks():
    """A round with 4x the schedule ticks and 4x the wall time is the
    same per-tick rate — not an incident."""
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4)
    for _ in range(8):
        w.record(1.0, ticks=1)
    assert not w.record(4.0, ticks=4)
    assert w.record(4.0, ticks=1)          # same time, 1 tick: straggling


def test_watchdog_reset_clears_history():
    w = StragglerWatchdog(window=16, threshold=2.0, min_samples=4)
    for _ in range(8):
        w.record(1.0)
    for _ in range(3):
        w.record(9.0)
    assert w.should_replace
    w.reset()
    assert not w.should_replace
    assert w.incidents == 0 and not w.times


def test_elastic_controller_drops_and_degrades():
    """The closed loop: per-rank watchdogs consume round ticks, the
    persistent straggler is dropped, and the collective schedules degrade
    onto the survivors (drop the rank, degrade the schedules, keep
    serving)."""
    from repro.core.schedule import (make_broadcast_schedule,
                                     make_ring_schedule, make_schedule)

    ctrl = ElasticController(n_ranks=4, min_samples=4, replace_after=3)
    healthy = {r: 1.0 for r in range(4)}
    for _ in range(8):
        assert ctrl.observe_round(healthy) == ()
    dropped = []
    for _ in range(4):            # rank 2 straggles persistently
        dropped += ctrl.observe_round({0: 1.0, 1: 1.0, 2: 5.0, 3: 1.0})
    assert dropped == [2]
    assert ctrl.live_ranks == (0, 1, 3)

    sched = make_schedule((100, 80, 60, 40))
    dsched = ctrl.degrade(sched)
    assert dsched.n == 3 and sum(dsched.counts) == sum(sched.counts)
    assert ctrl.degrade(make_broadcast_schedule(4, 512, 128)).n == 3
    assert ctrl.degrade(make_ring_schedule(4, 512, 64)).steps == 2
    # further observations about the dead rank are ignored
    ctrl.observe_round({2: 50.0, 0: 1.0})
    assert ctrl.live_ranks == (0, 1, 3)


def test_elastic_controller_keeps_last_survivor():
    ctrl = ElasticController(n_ranks=2)
    ctrl.drop(0)
    with pytest.raises(RuntimeError):
        ctrl.drop(1)
    assert ctrl.live_ranks == (1,)


def test_preemption_guard_catches_sigterm():
    with PreemptionGuard() as g:
        assert not g.requested
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.requested
    # handler restored after exit
    assert signal.getsignal(signal.SIGTERM) != g._handler


def test_kill_resume_loss_equivalence(tmp_path):
    """A preempted+resumed run reproduces the uninterrupted loss curve."""
    import jax
    from repro.configs import get_arch, reduced
    from repro.train import TrainConfig, train

    cfg = reduced(get_arch("llama3.2-1b"))
    t_int = TrainConfig(steps=12, global_batch=4, seq_len=32,
                        ckpt_dir=str(tmp_path / "a"), ckpt_every=6,
                        log_every=100)
    la, _, _ = train(cfg, t_int, verbose=False, max_steps_this_run=6)
    lb, _, _ = train(cfg, t_int, verbose=False)         # resumes at 6
    t_full = TrainConfig(steps=12, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path / "b"), ckpt_every=100,
                         log_every=100)
    lf, _, _ = train(cfg, t_full, verbose=False)
    np.testing.assert_allclose(la + lb, lf, atol=1e-5)
