"""End-to-end behaviour tests for the paper's system.

1. The framework trains: loss decreases on the structured synthetic stream.
2. The serving engine generates and the disaggregated prefill/decode handoff
   is equivalent to the monolithic path.
3. The CUCo pipeline (analyzer -> fast path -> slow path) discovers a
   co-design strategy at least as good as its conservative seed.
4. The cascade rejects broken candidates with routable diagnostics.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import (Candidate, CascadeEvaluator, MetaSummarizer,
                        SlowPathConfig, Directive,
                        extract_hardware_context, fast_path, slow_path)
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, train
from repro.workloads import get_workload


def test_training_reduces_loss(tmp_path):
    cfg = reduced(get_arch("llama3.2-1b"))
    tcfg = TrainConfig(steps=40, global_batch=8, seq_len=64,
                       ckpt_dir=str(tmp_path), ckpt_every=20, log_every=100)
    losses, last, _ = train(cfg, tcfg, verbose=False)
    assert last == 40
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02, (
        losses[:5], losses[-5:])


def test_moe_training_reduces_loss():
    cfg = reduced(get_arch("granite-moe-3b-a800m"))
    tcfg = TrainConfig(steps=30, global_batch=8, seq_len=64, log_every=100)
    losses, _, _ = train(cfg, tcfg, verbose=False)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_serving_and_disaggregation():
    cfg = reduced(get_arch("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_seq=64))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)),
        jnp.int32)}
    toks = eng.generate(batch, 6)
    assert toks.shape == (2, 6)
    handoff = eng.prefill_remote(batch)
    toks2 = eng.decode_from_handoff(handoff, 6)
    assert np.array_equal(np.asarray(toks), np.asarray(toks2))


def test_sampling_keys_advance_between_batches():
    """Regression: prefill() used to re-create PRNGKey(seed) on every call,
    so every temperature>0 batch sampled with the identical key. The engine
    now threads one split key stream through prefill/generate/decode —
    repeated sampled generations differ, while re-seeding a fresh engine
    reproduces the stream exactly."""
    cfg = reduced(get_arch("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)),
        jnp.int32)}
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=1.0,
                                          seed=7))
    a = np.asarray(eng.generate(batch, 8))
    b = np.asarray(eng.generate(batch, 8))
    assert not np.array_equal(a, b)       # the key stream advanced
    # determinism: a fresh engine with the same seed replays the stream
    eng2 = Engine(cfg, params, ServeConfig(max_seq=64, temperature=1.0,
                                           seed=7))
    assert np.array_equal(a, np.asarray(eng2.generate(batch, 8)))
    # the disaggregated path draws from the same stream: prefill_remote +
    # decode_from_handoff consumes keys just like the monolithic path
    eng3 = Engine(cfg, params, ServeConfig(max_seq=64, temperature=1.0,
                                           seed=7))
    handoff = eng3.prefill_remote(batch)
    c = np.asarray(eng3.decode_from_handoff(handoff, 8))
    assert np.array_equal(a, c)
    # the continuous-batching serve path draws from per-request fold_in
    # streams instead: a request's samples survive batch reassembly (the
    # scheduler regrouping rows across steps must not perturb any stream),
    # and serving does not consume the engine-level generate() stream
    from repro.serve import Request, Scheduler
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (3, 4))

    def serve(max_batch, rids):
        eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=1.0,
                                              seed=7))
        s = Scheduler(token_budget=12, max_batch=max_batch)
        for r in rids:
            s.submit(Request(r, tuple(int(t) for t in prompts[r]),
                             max_new_tokens=4 + r))
        return eng.serve(s), eng

    together, eng4 = serve(3, [0, 1, 2])
    alone, _ = serve(1, [1])
    assert np.array_equal(together[1], alone[1])
    assert not np.array_equal(together[0][:4], together[1][:4])
    # generate() after serve() replays the untouched engine stream
    assert np.array_equal(a, np.asarray(eng4.generate(batch, 8)))


def test_cuco_discovers_codesign():
    mesh = make_mesh((1,), ("x",))
    hw = extract_hardware_context(mesh)
    w = get_workload("gemm_allgather", n_dev=1, M=4096, K=4096, N=4096)
    seed = fast_path(w, mesh, hw)
    res = slow_path(seed, mesh, hw,
                    SlowPathConfig(islands=2, generations=4, seed=0))
    assert res.best.result.ok
    assert res.best.score >= res.seed_score * 0.999


def test_cascade_rejects_invalid_directive():
    mesh = make_mesh((1,), ("x",))
    hw = extract_hardware_context(mesh)
    w = get_workload("moe_dispatch", n_dev=1, tokens_per_rank=64, d=32, f=64)
    ev = CascadeEvaluator(w, mesh, hw)
    bad = Directive("PALLAS_RDMA", "COUNTER", "DEFERRED")
    cand = Candidate(directive=bad)
    res = ev.evaluate(cand)
    assert res.level == 0 and res.score == 0.0
    assert "invalid directive" in res.diagnostic


def test_meta_summarizer_produces_recommendations():
    from repro.core.cascade import EvalResult
    from repro.core.database import CandidateDB
    db = CandidateDB()
    meta = MetaSummarizer(every=2)
    for i, placement in enumerate(["DEFERRED", "STREAM_SPLIT"]):
        c = Candidate(directive=Directive(placement=placement), gen=i)
        c.result = EvalResult(3, 100.0 * (i + 1), 1.0)
        db.add(c)
        meta.observe(c)
    digest, recs = meta.summarize(2, db)
    assert digest["evaluated"] >= 1
    assert any(r["kind"] == "try_behavior" for r in recs)


def test_expert_directives_buildable():
    """Expert-system points (paper Table 3) build + verify on a 1-rank mesh."""
    from repro.core import EXPERT_SYSTEMS
    mesh = make_mesh((1,), ("x",))
    hw = extract_hardware_context(mesh)
    w = get_workload("gemm_allgather", n_dev=1)
    ev = CascadeEvaluator(w, mesh, hw)
    for name, d in EXPERT_SYSTEMS.items():
        cand = Candidate(directive=d)
        res = ev.evaluate(cand)
        assert res.ok, (name, res.diagnostic)
