"""Serving-tier suite at 4 simulated ranks: the executable acceptance gate
of the kernelized serving path (ISSUE-8).

Covers:
  * the ``serving_step`` workload cascades to l3 for the TokenWeave, FLUX
    and DeepEP (NVL) points (l2 at a reduced instance — interpret mode at
    the DeepSeek-V3 decode shape is prohibitively slow), and at the full
    serving shape every point's ``schedule_timeline`` critical path equals
    ``analytic_cost``;
  * the two-stream kernel itself: the shared-expert FFN is issued against
    the open dispatch send window (``ScheduleProbe`` marks
    ``dispatch_issued → shared_ffn → dispatch_drained``) and its numerics
    match the routed+shared oracle;
  * the engine decode step through ``kernels/moe_dispatch`` (FLUX point,
    ``StepOptions(moe_backend="pallas", moe_overlap=True)``) emits exactly
    the host path's greedy tokens — both one-shot and through the
    continuous-batching ``serve`` loop;
  * the prefill→decode cache handoff rides ``kernels/kv_shuttle``
    (``prefill_remote(shuttle_mesh=...)``) bit-exactly;
  * degraded-mode serving: drop a rank mid-run via ``ElasticController``
    + ``engine.degrade`` — the engine keeps emitting tokens;
  * the deterministic ``BENCH_serving.json`` tokens/s artifact is
    (re)generated at ``--out`` — the checked-in copy must match.
"""
import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2]))

from benchmarks.common import write_rows
from repro.compat import make_mesh
from repro.configs import get_arch, reduced
from repro.core import extract_hardware_context
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import CONSERVATIVE, EXPERT_SYSTEMS
from repro.core.trace import ScheduleProbe, schedule_timeline, validate_trace
from repro.dist.sharding import Rules
from repro.kernels.moe_dispatch import moe_dispatch_combine
from repro.models import StepOptions, init_params
from repro.serve import Engine, Request, Scheduler, ServeConfig
from repro.train.fault_tolerance import ElasticController
from repro.workloads import get_workload

args = argparse.ArgumentParser()
args.add_argument("--out", default="BENCH_serving.json",
                  help="path for the serving tokens/s benchmark artifact")
A = args.parse_args()

assert jax.device_count() >= 4, jax.device_count()
key = jax.random.PRNGKey(7)
mesh = make_mesh((4,), ("x",))
hw = extract_hardware_context(mesh)
FLUX = EXPERT_SYSTEMS["FLUX"]

# ---- cascade: the serving step's overlap points reach l3 ------------------
wred = get_workload("serving_step", n_dev=4, tokens_per_rank=96, d=128,
                    f=192, f_shared=192)
ev = CascadeEvaluator(wred, mesh, hw)
for name in ("TokenWeave", "FLUX", "DeepEP (NVL)"):
    res = ev.evaluate(Candidate(directive=EXPERT_SYSTEMS[name]))
    assert res.level == 3, (name, res.level, res.diagnostic)
    assert res.score > 0
    print(f"cascade {name} l3 ok ({res.diagnostic})")

# ---- two-stream kernel: second stream inside the send window --------------
x, w1, w2, s1, s2 = wred.example_inputs(key, mesh)
ref = np.asarray(wred.reference(x, w1, w2, s1, s2))
probe = ScheduleProbe()
k = wred.kernel_knobs(FLUX)
y, ys = moe_dispatch_combine(
    x, w1, w2, mesh, axis="x", counts=wred._counts(x.shape[1]),
    block_tokens=k["block_tokens"], tight=k["tight"],
    pipelined=k["pipelined"], barrier=k["barrier"],
    tile_fused=k["tile_fused"], combine_tile=k["combine_tile"],
    contexts=k["contexts"], wire_i8=False, shared=(x, s1, s2), probe=probe)
out = np.asarray(y + ys)
err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
assert err < 2e-3, err
assert probe.marks == ["dispatch_issued", "shared_ffn", "dispatch_drained"], \
    probe.marks
print(f"two-stream kernel ok (err {err:.1e}; marks {probe.marks})")

# ---- full serving shape: timelines + modeled tokens/s rows ----------------
w = get_workload("serving_step")            # 4 x 256 tokens, d=7168, f=2048
host_cost = w.analytic_cost(CONSERVATIVE, hw)
rows = []
for row_name, d in (("host_sequential", CONSERVATIVE),
                    ("tokenweave_stream_split", EXPERT_SYSTEMS["TokenWeave"]),
                    ("deepep_nvl_deferred", EXPERT_SYSTEMS["DeepEP (NVL)"]),
                    ("flux_two_stream", FLUX)):
    assert w.check(d, hw) == [], (row_name, w.check(d, hw))
    tl = schedule_timeline(w, d, hw)
    validate_trace(tl.to_dict())
    cost = w.analytic_cost(d, hw)
    assert abs(tl.critical_path_s - cost) < 1e-6, (row_name,
                                                   tl.critical_path_s, cost)
    assert cost <= host_cost + 1e-12, (row_name, cost, host_cost)
    tok_s = w.n_dev * w.T / cost
    rows.append((f"serving_step/{row_name}", cost * 1e6,
                 f"tokens_per_s={tok_s:.0f}"))
    print(f"{row_name}: {cost*1e3:.3f} ms modeled "
          f"({tok_s:,.0f} tok/s; critical path == analytic_cost)")
bench = write_rows(A.out, rows)
assert len(bench["rows"]) == 4
print(f"bench artifact ok ({A.out})")

# ---- engine: kernelized decode parity + continuous batching ---------------
cfg = reduced(get_arch("llama4-maverick-400b-a17b"), num_experts=4,
              experts_per_token=1, pad_to=2, capacity_factor=16.0)
rules = Rules(make_mesh((4,), ("data",)), "decode")
params = init_params(jax.random.PRNGKey(0), cfg)


def requests(n_new=4):
    return [Request(i, (1 + i, 2 + i, 3 + i, 4 + i), max_new_tokens=n_new)
            for i in range(4)]


def serve_run(opts, on_step=None):
    eng = Engine(cfg, params, ServeConfig(max_seq=32, seed=0, opts=opts),
                 rules=rules)
    s = Scheduler(token_budget=16, max_batch=4, metrics=eng.metrics)
    for r in requests():
        s.submit(r)
    return eng.serve(s, on_step=on_step), eng


host_out, _ = serve_run(StepOptions(remat=False))
pal_out, eng = serve_run(StepOptions(remat=False, moe_backend="pallas",
                                     moe_overlap=True))
assert sorted(pal_out) == [0, 1, 2, 3]
for rid in host_out:
    assert np.array_equal(host_out[rid], pal_out[rid]), (
        rid, host_out[rid], pal_out[rid])
c = eng.metrics.snapshot()["counters"]
assert c["serve.decode_steps"] == 3 and c["serve.tokens_generated"] == 12
assert c["sched.finished"] == 4
print("kernelized serve parity ok (pallas decode == host greedy tokens)")

# ---- prefill -> decode cache handoff over the kv_shuttle kernel -----------
lcfg = reduced(get_arch("llama3.2-1b"))
lparams = init_params(jax.random.PRNGKey(0), lcfg)
leng = Engine(lcfg, lparams, ServeConfig(max_seq=16, seed=0))
batch = {"tokens": jnp.arange(1, 9, dtype=jnp.int32).reshape(2, 4)}
mesh2 = make_mesh((2,), ("x",), devices=jax.devices()[:2])
ref_h = leng.prefill_remote(batch)
for kw in ({"chained": True}, {"fused": True, "counter": True,
                               "kv_chunk": 8}):
    h = leng.prefill_remote(batch, shuttle_mesh=mesh2, **kw)
    for blk in ref_h["cache"]:
        for leaf in ref_h["cache"][blk]:
            a = np.asarray(ref_h["cache"][blk][leaf])
            b = np.asarray(h["cache"][blk][leaf])
            assert np.array_equal(a, b), (kw, blk, leaf)
toks = leng.decode_from_handoff(h, 4)
assert toks.shape == (2, 4)
print("kv_shuttle cache handoff ok (bit-exact, both shuttle realizations)")

# ---- degraded-mode serving: drop a rank mid-run ---------------------------
ctl = ElasticController(4)


def on_step(step_no, engine):
    if step_no == 1:
        ctl.drop(3)
        live = len(ctl.live_ranks) // 2 * 2      # even data-parallel width
        engine.degrade(jax.devices()[:live])


deg_out, deg_eng = serve_run(
    StepOptions(remat=False, moe_backend="pallas", moe_overlap=True),
    on_step=on_step)
assert sorted(deg_out) == [0, 1, 2, 3]
assert all(len(deg_out[r]) == 4 for r in deg_out)
dc = deg_eng.metrics.snapshot()["counters"]
assert dc["serve.degrades"] == 1
assert dc["serve.tokens_generated"] == 12
assert ctl.live_ranks == (0, 1, 2)
print("degraded serve ok (rank 3 dropped at step 1; all requests completed)")

print("ALL OK")
