"""Ring-workload kernels at simulated ranks: ring_attention (4-rank ring)
and kv_shuttle (2-rank prefill→decode), both realized against the shared
``core/schedule.py::RingSchedule``.

Covers the acceptance criteria that need devices:
  * the TILE_FUSED + COUNTER (FLUX-ring) point and the DEFERRED kernel
    point evaluate to l3 through the full cascade for BOTH ring workloads
    under interpret mode;
  * chunked kernel numerics match the oracle AND the executable host
    baseline across kv_chunk values (including a non-divisor the sanitizer
    must repair), completion/placement/ordering realizations, causal
    masks, and send-window depths;
  * a slow-path diff patch proposing any TUNABLES grid value survives the
    cascade (sanitizer coverage at 4 ranks);
  * race/deadlock freedom of the chunk-rotating path is proven by the
    static verifier (``core/verify.py`` — the same checker the cascade
    runs at l0, so there is exactly one race checker in the repo), and a
    seeded premature-slot-reuse mutation is caught. Unlike the old
    ``detect_races`` interpret hook this holds on legacy jax too.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extract_hardware_context
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import EXPERT_SYSTEMS, Directive
from repro.kernels.ref import kv_shuttle_ref, ring_attention_ref
from repro.kernels.kv_shuttle import kv_shuttle
from repro.kernels.ring_attention import ring_attention
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload

D = Directive
FLUX = EXPERT_SYSTEMS["FLUX"]
mesh4 = make_mesh((4,), ("x",))
mesh2 = make_mesh((2,), ("x",))
key = jax.random.PRNGKey(0)

# ---- cascade: the FLUX-ring (TILE_FUSED + COUNTER) and DEFERRED kernel
# points evaluate to l3 at 4 ranks under interpret mode. The workload
# carries the paper deployment shape (the l3 model's shape); example
# inputs shrink the executable l2 verify automatically.
w = get_workload("ring_attention", n_dev=4, BH=96, seq=4096, hd=64)
hw = extract_hardware_context(mesh4)
ev = CascadeEvaluator(w, mesh4, hw)

res_f = ev.evaluate(Candidate(directive=FLUX))
assert res_f.level == 3, (res_f.level, res_f.diagnostic)
assert res_f.score > 0
print(f"cascade ring_attention flux l3 ok ({res_f.diagnostic})")

deferred = D("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL", "KERNEL",
             "PER_PEER", "RELEASE", 2)
res_d = ev.evaluate(Candidate(directive=deferred))
assert res_d.level == 3, (res_d.level, res_d.diagnostic)
host_cost = w.analytic_cost(D("XLA_COLLECTIVE", placement="DEFERRED"), hw)
assert res_f.t_model_ms < res_d.t_model_ms < host_cost * 1e3
print("cascade ring_attention deferred l3 ok (flux < deferred < host)")

# a slow-path diff patch may propose any TUNABLES grid value — including a
# kv_chunk that does not divide Sl; the sanitizer must keep the evaluator
# alive and still reach l3
res_bad = ev.evaluate(Candidate(directive=FLUX.with_tunable("kv_chunk", 48)))
assert res_bad.level == 3, (res_bad.level, res_bad.diagnostic)
print("cascade ring_attention non-divisor kv_chunk ok (sanitized)")

# ---- cascade: kv_shuttle FLUX + chained points to l3 (2-rank shuttle,
# deployment shape for the l3 model; example inputs stay small)
wk = get_workload("kv_transfer")
hwk = extract_hardware_context(mesh2)
evk = CascadeEvaluator(wk, mesh2, hwk)
res_kf = evk.evaluate(Candidate(directive=FLUX))
assert res_kf.level == 3, (res_kf.level, res_kf.diagnostic)
res_kc = evk.evaluate(Candidate(
    directive=D("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT", contexts=2)))
assert res_kc.level == 3, (res_kc.level, res_kc.diagnostic)
assert res_kf.t_model_ms < res_kc.t_model_ms
print("cascade kv_shuttle flux + chained l3 ok (flux < chained)")

# ---- ring kernel numerics: chunked realizations vs oracle AND the
# executable host baseline bit-path
for (BH, Sl, hd) in [(2, 64, 64), (4, 128, 64), (1, 128, 128)]:
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (4, BH, Sl, hd),
                                 jnp.float32) for i in range(3))
    for causal in (True, False):
        ref = ring_attention_ref(q, k, v, causal=causal)
        for kw in [dict(fused=True, counter=True, kv_chunk=32, contexts=1),
                   dict(fused=True, counter=True, kv_chunk=32, contexts=2),
                   dict(fused=True, counter=True, kv_chunk=Sl, contexts=2),
                   dict(fused=True, counter=False, kv_chunk=32, contexts=2),
                   dict(fused=True, counter=True, kv_chunk=48, contexts=4),
                   dict(pipelined=True), dict(pipelined=True, eager_wait=True),
                   dict(pipelined=False)]:
            out = jax.jit(lambda a, b, c: ring_attention(
                a, b, c, mesh4, causal=causal, **kw))(q, k, v)
            assert not np.any(np.isnan(np.asarray(out))), (BH, Sl, hd, kw)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5,
                err_msg=str((BH, Sl, hd, causal, kw)))
print("ring_attention numerics ok (chunked flux/signal/pipelined/deferred)")

# the chunk-fused kernel also matches the executable host baseline bit-path
wv4 = get_workload("ring_attention", n_dev=4, BH=4, seq=512, hd=64)
inputs = wv4.example_inputs(jax.random.PRNGKey(9), mesh4)
host_out = np.asarray(jax.jit(wv4.host_baseline(mesh4))(*inputs))
flux_out = np.asarray(jax.jit(wv4.build(FLUX, mesh4))(*inputs))
err = np.max(np.abs(flux_out - host_out)) / (np.max(np.abs(host_out)) + 1e-9)
assert err < 2e-3, err
print("ring_attention flux matches host baseline")

# ---- kv_shuttle numerics: chunked + chained realizations
for (T, d, dk) in [(64, 128, 64), (128, 256, 128)]:
    x_real = jax.random.normal(key, (T, d), jnp.float32)
    x = jnp.stack([x_real, jnp.zeros_like(x_real)])
    wkm = jax.random.normal(jax.random.fold_in(key, 2), (d, dk), jnp.float32)
    wvm = jax.random.normal(jax.random.fold_in(key, 3), (d, dk), jnp.float32)
    kr, vr = kv_shuttle_ref(x_real, wkm, wvm)
    for kw in [dict(chained=True), dict(chained=False),
               dict(fused=True, counter=True, kv_chunk=32, contexts=2),
               dict(fused=True, counter=True, kv_chunk=T, contexts=1),
               dict(fused=True, counter=False, kv_chunk=48, contexts=4)]:
        ko, vo = kv_shuttle(x, wkm, wvm, mesh2, **kw)
        np.testing.assert_allclose(np.asarray(ko[1]), np.asarray(kr),
                                   atol=2e-4, rtol=2e-4, err_msg=str((T, kw)))
        np.testing.assert_allclose(np.asarray(vo[1]), np.asarray(vr),
                                   atol=2e-4, rtol=2e-4, err_msg=str((T, kw)))
print("kv_shuttle ok (chained + chunk-fused)")

# ---- race/deadlock freedom of the chunk-rotating path: the static
# verifier (the cascade's l0 checker — one checker for suite and search)
# proves the slot-reuse/credit-handshake contract over the whole ring
# grid, then must catch a seeded premature-slot-reuse mutation with a
# class-specific diagnostic.
from repro.core.schedule import make_ring_schedule
from repro.core.verify import apply_mutation, verify_program, verify_schedule

for n, fused, counter in [(4, True, True), (4, True, False),
                          (4, False, True), (2, True, True)]:
    sched = make_ring_schedule(n, 64, 32, fused)
    rep = verify_schedule(sched, knobs=dict(counter=counter))
    assert rep.ok, rep.summary()
    live = tuple(range(n - 1)) if n > 2 else None
    if live:
        drep = verify_schedule(sched.degrade(live), parent=sched, live=live)
        assert drep.ok, drep.summary()
print("static race verifier green over the ring grid (incl. degraded)")

from repro.core.verify import lower_ring

prog = lower_ring(make_ring_schedule(4, 64, 32, True), 2, counter=True)
mut = apply_mutation(prog, "premature_slot_reuse")
mrep = verify_program(mut)
assert not mrep.ok and mrep.errors[0].code == "slot-reuse", mrep.summary()
print(f"seeded slot-reuse race caught: {mrep.errors[0]}")
print("ALL OK")
