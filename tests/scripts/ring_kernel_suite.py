"""Ring attention kernel: placement/ordering variants + race detector."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.kernels.ref import ring_attention_ref
from repro.kernels.ring_attention import (ring_attention,
                                          ring_attention_sharded)
from repro.compat import interpret_params, shard_map
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("x",))
key = jax.random.PRNGKey(0)

for (BH, Sl, hd) in [(2, 64, 64), (4, 128, 64), (1, 128, 128)]:
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (4, BH, Sl, hd),
                                 jnp.float32) for i in range(3))
    for causal in (True, False):
        ref = ring_attention_ref(q, k, v, causal=causal)
        for pipelined, eager in [(True, False), (True, True), (False, False)]:
            out = jax.jit(lambda a, b, c: ring_attention(
                a, b, c, mesh, causal=causal, pipelined=pipelined,
                eager_wait=eager))(q, k, v)
            assert not np.any(np.isnan(np.asarray(out))), \
                (BH, Sl, hd, causal, pipelined, eager)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5,
                err_msg=str((BH, Sl, hd, causal, pipelined, eager)))

# race detector on the pipelined path — only meaningful on jax with the
# InterpretParams simulator; the legacy interpreter has no race detection,
# so running it there would be a vacuous pass. Say so instead of faking it.
from repro.compat import LEGACY_INTERPRET

if LEGACY_INTERPRET:
    print("race detector unavailable on legacy jax (skipped)")
else:
    ip = interpret_params(detect_races=True, dma_execution_mode="eager")
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (4, 2, 64, 64),
                                 jnp.float32) for i in range(3))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("x"),
                       out_specs=P("x"), check_vma=False)
    def run(qs, ks, vs):
        return ring_attention_sharded(qs[0], ks[0], vs[0], axis="x", n_dev=4,
                                      causal=True, pipelined=True,
                                      interpret=ip)[None]

    import contextlib
    import io

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        out = run(q, k, v)
    assert "RACE DETECTED" not in buf.getvalue(), buf.getvalue()[:2000]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ring_attention_ref(q, k, v)),
                               atol=2e-5, rtol=2e-5)
print("ALL OK")
