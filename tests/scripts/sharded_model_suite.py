"""Sharded model equivalence on an 8-device (4 data x 2 model) mesh:
train loss, prefill, decode for one arch per family + MoE mode checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, reduced
from repro.dist.sharding import Rules, sanitize_specs
from repro.compat import set_mesh
from repro.launch.mesh import make_mesh
from repro.models import (decode_step, init_params, param_specs,
                          prefill_step, train_loss)
from repro.models.moe import moe_apply, moe_init

mesh = make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)

for name in ["llama3.2-1b", "xlstm-350m", "recurrentgemma-9b",
             "whisper-large-v3", "granite-20b"]:
    cfg = reduced(get_arch(name))
    params = init_params(key, cfg)
    B, S = 8, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    l_ref = float(train_loss(params, batch, cfg, None))
    lo_ref, cache_ref = prefill_step(
        params, {k: v for k, v in batch.items() if k != "labels"}, cfg, None,
        seq_len=S + 4)
    tok = jnp.argmax(lo_ref, -1).astype(jnp.int32)
    lo2_ref, _ = decode_step(params, cache_ref, tok, jnp.int32(S), cfg, None)

    rules_t = Rules(mesh, "train")
    rules_d = Rules(mesh, "decode")
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), key)
    specs = sanitize_specs(param_specs(cfg, rules_t), shapes, mesh)
    with set_mesh(mesh):
        pl_ = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda s: isinstance(s, P)))
        l_sh = float(jax.jit(lambda p, b: train_loss(p, b, cfg, rules_t))(
            pl_, batch))
        lo, cache = jax.jit(lambda p, b: prefill_step(
            p, b, cfg, Rules(mesh, "prefill"), seq_len=S + 4))(
            pl_, {k: v for k, v in batch.items() if k != "labels"})
        lo2, _ = jax.jit(lambda p, c, t, po: decode_step(
            p, c, t, po, cfg, rules_d))(pl_, cache, tok, jnp.int32(S))
    assert abs(l_ref - l_sh) < 5e-2, (name, l_ref, l_sh)
    e = float(jnp.max(jnp.abs(lo2 - lo2_ref)))
    assert e < 6e-2, (name, e)
    print(name, "ok")

# MoE modes agree with the local oracle when capacity is drop-free
cfgm = reduced(get_arch("llama4-maverick-400b-a17b"), num_experts=8,
               experts_per_token=2, pad_to=2, capacity_factor=16.0)
p = moe_init(key, cfgm, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfgm.d_model),
                      jnp.float32)
y_ref = moe_apply(p, x, cfgm, None)
rules = Rules(mesh, "train")
with set_mesh(mesh):
    for mode in ("replicated", "alltoall"):
        cm = dataclasses.replace(cfgm, ep_mode=mode)
        for ov in (False, True):
            y = jax.jit(lambda pp, xx: moe_apply(pp, xx, cm, rules,
                                                 overlap=ov))(p, x)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       atol=1e-4, rtol=1e-4,
                                       err_msg=f"{mode} overlap={ov}")
    yq = jax.jit(lambda pp, xx: moe_apply(
        pp, xx, dataclasses.replace(cfgm, ep_mode="alltoall"), rules,
        overlap=True, quantize=True))(p, x)
    rel = float(jnp.linalg.norm(yq - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.02, rel
print("moe modes ok")
print("ALL OK")
