"""Device-initiated MoE dispatch/combine suite (the DeepEP-analogue kernel).

Covers the ISSUE-1 acceptance criteria that need simulated devices:
  * every Table-3 expert directive validates under the (now kernelizable)
    moe_dispatch traits, and the DeepEP (NVL) point evaluates to l3 through
    the full cascade (l1 build/lower -> l2 interpret-mode verify -> l3);
  * kernel numerics match the oracle across skews, paddings, block sizes,
    completion/placement/context realizations, and the int8 wire;
  * the schedule's tight wire accounting beats the padded baseline.

``--n-dev`` reshapes the suite (the executable counterpart of the fig4
``--n-dev 8`` analytic sweep — ROADMAP open item). Interpret mode is orders
of magnitude slower than hardware, so any ``--n-dev`` other than the
default 4 runs a budget-capped subset: tiny shapes, one cascade-to-l3 per
kernelized point, one numerics verify each for the tight and FLUX paths.
"""
import argparse

import jax
import numpy as np

from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import EXPERT_SYSTEMS, Directive
from repro.core import extract_hardware_context
from repro.kernels.moe_dispatch import make_schedule
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload

D = Directive
args = argparse.ArgumentParser()
args.add_argument("--n-dev", type=int, default=4,
                  help="mesh size (must match the simulated device count)")
N_DEV = args.parse_args().n_dev
key = jax.random.PRNGKey(7)

if N_DEV != 4:
    # ---- budget-capped sweep at a non-default rank count ----------------
    mesh = make_mesh((N_DEV,), ("x",))
    w = get_workload("moe_dispatch", n_dev=N_DEV, tokens_per_rank=64, d=32,
                     f=64, skew=3.0)
    hw = extract_hardware_context(mesh)
    for name, d in EXPERT_SYSTEMS.items():
        v = w.check(d, hw)
        assert not v, (name, v)
    print(f"table3 directives valid ok (n_dev={N_DEV})")

    ev = CascadeEvaluator(w, mesh, hw)
    res = ev.evaluate(Candidate(directive=EXPERT_SYSTEMS["DeepEP (NVL)"]))
    assert res.level == 3, (res.level, res.diagnostic)
    print(f"cascade deepep_nvl l3 ok at {N_DEV} ranks ({res.diagnostic})")
    res_f = ev.evaluate(Candidate(directive=EXPERT_SYSTEMS["FLUX"]))
    assert res_f.level == 3, (res_f.level, res_f.diagnostic)
    print(f"cascade flux l3 ok at {N_DEV} ranks ({res_f.diagnostic})")

    inputs = w.example_inputs(key, mesh)
    ref = np.asarray(w.reference(*inputs))
    tight = D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
              "GRID_STEP", "PER_PEER", "ACQUIRE", 2,
              tunables=(("tight", 1), ("block_tokens", 16)))
    for d in (tight, EXPERT_SYSTEMS["FLUX"].with_tunable("block_tokens",
                                                         16)):
        out = np.asarray(jax.jit(w.build(d, mesh))(*inputs))
        err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 2e-3, (d.placement, d.completion, err)
    print(f"kernel numerics ok at {N_DEV} ranks")

    # tight wire still beats padded at the wider mesh
    counts = w._counts(w.T)
    st = make_schedule(counts, block_tokens=16, tight=True)
    sp = make_schedule(counts, block_tokens=16, tight=False)
    assert st.wire_tokens(0) < sp.wire_tokens(0)
    print("tight wire accounting ok")
    print("ALL OK")
    raise SystemExit(0)

mesh = make_mesh((4,), ("x",))

w = get_workload("moe_dispatch", n_dev=4, tokens_per_rank=256, d=128, f=256,
                 skew=3.0)
hw = extract_hardware_context(mesh)

# ---- Table-3 reachability: all expert points are valid for this workload
for name, d in EXPERT_SYSTEMS.items():
    v = w.check(d, hw)
    assert not v, (name, v)
print("table3 directives valid ok")

# ---- cascade: the DeepEP (NVL) point reaches l3 under interpret mode
ev = CascadeEvaluator(w, mesh, hw)
cand = Candidate(directive=EXPERT_SYSTEMS["DeepEP (NVL)"])
res = ev.evaluate(cand)
assert res.level == 3, (res.level, res.diagnostic)
assert res.score > 0
print(f"cascade deepep_nvl l3 ok ({res.diagnostic})")

# the pipelined tight refinement also reaches l3 and models faster
tight = D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
          "PER_PEER", "ACQUIRE", 2, tunables=(("tight", 1),))
res_t = ev.evaluate(Candidate(directive=tight))
assert res_t.level == 3, (res_t.level, res_t.diagnostic)
assert res_t.t_model_ms < res.t_model_ms, (res_t.t_model_ms, res.t_model_ms)
print("cascade deepep_tight l3 ok (beats NVL point)")

# the FLUX point (TILE_FUSED + COUNTER: tile-fused expert GEMM, per-tile
# combine writes) evaluates to l3 through the same cascade
res_f = ev.evaluate(Candidate(directive=EXPERT_SYSTEMS["FLUX"]))
assert res_f.level == 3, (res_f.level, res_f.diagnostic)
assert res_f.score > 0
assert res_f.t_model_ms < res.t_model_ms, (res_f.t_model_ms, res.t_model_ms)
print(f"cascade flux l3 ok ({res_f.diagnostic})")

# ---- kernel numerics across realizations
inputs = w.example_inputs(key, mesh)
ref = np.asarray(w.reference(*inputs))


def verify(d, tol=2e-3):
    out = np.asarray(jax.jit(w.build(d, mesh))(*inputs))
    err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < tol, (d.backend, d.placement, d.completion, err)


verify(D("PALLAS_RDMA", "SIGNAL", "DEFERRED", "WORLD", "KERNEL",
         "PER_PEER", "ACQUIRE", 1))                    # DeepEP (IB) point
verify(D("HYBRID", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
         "PER_PEER", "ACQUIRE", 2))
verify(D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
         "PER_CHUNK", "ACQUIRE", 2))                   # padded-kernel ablation
verify(D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
         "PER_PEER", "ACQUIRE", 4).with_tunable("block_tokens", 32))
verify(D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
         "PER_PEER", "ACQUIRE", 2).with_tunable("wire_i8", 1), tol=8e-2)
print("kernel realizations ok")

# ---- FLUX realizations: tile-fused expert GEMM, per-tile combine writes
flux = EXPERT_SYSTEMS["FLUX"]
verify(flux)                                            # Table-3 coordinates
verify(flux.with_tunable("combine_tile", 16))           # sub-tile counters
verify(flux.with_tunable("block_tokens", 32))
verify(flux.with_tunable("wire_i8", 1), tol=8e-2)
verify(D("PALLAS_RDMA", "SIGNAL", "TILE_FUSED", "LOCAL", "GRID_STEP",
         "PER_TILE", "ACQREL", 2))                      # signal-fused variant
verify(D("HYBRID", "COUNTER", "TILE_FUSED", "LOCAL", "GRID_STEP",
         "PER_TILE", "ACQREL", 1))

# the tile-fused kernel also matches the executable host baseline bit-path
host_out = np.asarray(jax.jit(w.host_baseline(mesh))(*inputs))
flux_out = np.asarray(jax.jit(w.build(flux, mesh))(*inputs))
err = np.max(np.abs(flux_out - host_out)) / (np.max(np.abs(host_out)) + 1e-9)
assert err < 2e-3, err
print("flux realizations ok (matches host baseline)")

# ---- skew sweep incl. a zero-count expert tail
for skew in (2.0, 5.0):
    ws = get_workload("moe_dispatch", n_dev=4, tokens_per_rank=128, d=64,
                      f=128, skew=skew)
    ins = ws.example_inputs(key, mesh)
    r = np.asarray(ws.reference(*ins))
    o = np.asarray(jax.jit(ws.build(tight, mesh))(*ins))
    err = np.max(np.abs(o - r)) / (np.max(np.abs(r)) + 1e-9)
    assert err < 2e-3, (skew, err)
print("skew sweep ok")

# ---- tight-wire schedule accounting
for skew in (2.0, 3.0, 4.0, 5.0):
    ws = get_workload("moe_dispatch", n_dev=4, tokens_per_rank=4096, d=64,
                      f=128, skew=skew)
    counts = ws._counts(ws.T)
    st = make_schedule(counts, block_tokens=64, tight=True)
    sp = make_schedule(counts, block_tokens=64, tight=False)
    assert st.wire_tokens(0) == int(counts.sum() - counts[0])
    assert sp.wire_tokens(0) == int(counts.max()) * (len(counts) - 1)
    assert st.wire_tokens(0) < sp.wire_tokens(0), skew
    assert st.executed_wire_tokens(0) < sp.executed_wire_tokens(0), skew
print("tight wire accounting ok")

print("ALL OK")
