"""Fault-injection suite at simulated ranks (default 4): the executable
acceptance gate of the degraded-mode schedule layer (core/schedule.py
``degrade`` + core/faults.py).

Covers, per workload:
  * a dropped-peer plan reshapes the workload onto the survivors and the
    **degraded schedule runs the unmodified kernel** through the full
    cascade on the surviving mesh — l2 interpret completes with finite
    outputs (degrade, don't hang: no DMA to, no semaphore wait on, the
    dead rank) and l3 prices finite;
  * the l3 fault charge is strictly greater than healthy but finite
    (degraded rounds + recovery wire + remesh);
  * straggler rounds are charged through ``window_stall_factor`` (deeper
    send windows absorb more of the blip) and surface in
    ``EvalResult.fault_report``;
  * corrupted / truncated wire payloads injected at l2
    (``inject_wire_fault``) are *classified* by the evaluator — non-finite
    and rel-err diagnostics — never crashes;
  * a wedged candidate is quarantined at the wall-clock deadline and the
    evaluator keeps serving the next candidate (slow_path can never
    stall).

Emits the healthy-vs-degraded modeled numbers per workload to
``--out`` (BENCH_faults.json — the repo's first benchmark artifact).
"""
import argparse
import json
import math
import time

import jax

from repro.core import extract_hardware_context
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import EXPERT_SYSTEMS, Directive
from repro.core.faults import (CORRUPT_WIRE, DROPPED_PEER, STRAGGLER,
                               TRUNCATED_WIRE, FaultPlan, FaultSpec,
                               fault_cost, inject_wire_fault)
from repro.compat import make_mesh
from repro.workloads import get_workload

args = argparse.ArgumentParser()
args.add_argument("--out", default="BENCH_faults.json",
                  help="path for the healthy-vs-degraded benchmark artifact")
A = args.parse_args()

FLUX = EXPERT_SYSTEMS["FLUX"]
key = jax.random.PRNGKey(11)
mesh4 = make_mesh((4,), ("x",), devices=jax.devices()[:4])
hw = extract_hardware_context(mesh4)

DROP1 = FaultPlan("drop-rank-1", (FaultSpec(DROPPED_PEER, rank=1),))
STRAG = FaultPlan("straggler-8x100us",
                  (FaultSpec(STRAGGLER, rank=2, rounds=8, delay_s=100e-6),))

bench = {"directive": "FLUX", "plan": DROP1.name, "workloads": {}}

# ---- dropped peer: every workload degrades, the kernels run the degraded
# schedules unmodified on the surviving mesh, the cascade reaches l3 -------
WORKLOADS = ("moe_dispatch", "ring_attention", "gemm_allgather",
             "kv_transfer")
for name in WORKLOADS:
    w = get_workload(name)
    live = DROP1.live_ranks(w.n_dev)
    dw = w.degrade(live)
    assert dw.n_dev == w.n_dev - 1
    dmesh = make_mesh((dw.n_dev,), ("x",), devices=jax.devices()[:dw.n_dev])
    dhw = extract_hardware_context(dmesh)
    ev = CascadeEvaluator(dw, dmesh, dhw)
    res = ev.evaluate(Candidate(directive=FLUX))
    # level 3 == the degraded schedule completed l2 interpret with finite
    # outputs (the evaluator's finite check) and priced finite at l3
    assert res.level == 3, (name, res.level, res.diagnostic)
    assert math.isfinite(res.t_model_ms)
    healthy_ms = w.analytic_cost(FLUX, hw) * 1e3
    degraded_ms = fault_cost(w, FLUX, hw, DROP1) * 1e3
    assert math.isfinite(degraded_ms) and degraded_ms > healthy_ms, (
        name, healthy_ms, degraded_ms)
    bench["workloads"][name] = {
        "n_healthy": w.n_dev, "n_degraded": dw.n_dev,
        "healthy_ms": round(healthy_ms, 6),
        "degraded_ms": round(degraded_ms, 6),
        "survives": True,
    }
    print(f"dropped-peer {name}: degraded cascade l3 ok "
          f"({healthy_ms:.3f} -> {degraded_ms:.3f} ms)")

# ---- straggler: charged at l3 via window_stall_factor, and surfaced on
# EvalResult.fault_report through a real degraded-ring cascade ------------
w = get_workload("ring_attention")
shallow = Directive("PALLAS_RDMA", "COUNTER", "TILE_FUSED", "LOCAL",
                    "GRID_STEP", "PER_TILE", "ACQREL", 1)
deep = Directive("PALLAS_RDMA", "COUNTER", "TILE_FUSED", "LOCAL",
                 "GRID_STEP", "PER_TILE", "ACQREL", 4)
stall_1 = fault_cost(w, shallow, hw, STRAG) - w.analytic_cost(shallow, hw)
stall_4 = fault_cost(w, deep, hw, STRAG) - w.analytic_cost(deep, hw)
assert stall_1 > stall_4 > 0, (stall_1, stall_4)
bench["straggler"] = {"plan": STRAG.name,
                      "stall_ms_contexts_1": round(stall_1 * 1e3, 6),
                      "stall_ms_contexts_4": round(stall_4 * 1e3, 6)}
print(f"straggler stall: contexts=1 {stall_1*1e3:.3f} ms > "
      f"contexts=4 {stall_4*1e3:.3f} ms (window-absorbed)")

dw = w.degrade((0, 2, 3))
dmesh = make_mesh((3,), ("x",), devices=jax.devices()[:3])
ev = CascadeEvaluator(dw, dmesh, extract_hardware_context(dmesh),
                      fault_plans=(FaultPlan(
                          "drop-another",
                          (FaultSpec(DROPPED_PEER, rank=2),)), STRAG),
                      fault_weight=1.0)
res = ev.evaluate(Candidate(directive=FLUX))
assert res.level == 3 and set(res.fault_report) == {"drop-another",
                                                    STRAG.name}
assert all(e["survives"] for e in res.fault_report.values())
print("fault-survival report attached at l3 "
      f"({ {k: round(v['degraded_ms'], 3) for k, v in res.fault_report.items()} })")

# ---- wire faults injected at l2: the evaluator classifies, never crashes -
wk = get_workload("kv_transfer")


class FaultyWire(type(wk)):
    spec = None

    def build(self, d, mesh):
        fn = super().build(d, mesh)
        return lambda *xs: inject_wire_fault(fn(*xs), self.spec)


mesh2 = make_mesh((2,), ("x",), devices=jax.devices()[:2])
hw2 = extract_hardware_context(mesh2)
fw = FaultyWire()
fw.spec = FaultSpec(CORRUPT_WIRE, rows=4)
res = CascadeEvaluator(fw, mesh2, hw2).evaluate(Candidate(directive=FLUX))
assert res.level == 1 and "non-finite" in res.diagnostic, res.diagnostic
fw.spec = FaultSpec(TRUNCATED_WIRE, rows=64)
res = CascadeEvaluator(fw, mesh2, hw2).evaluate(Candidate(directive=FLUX))
assert res.level == 1 and "rel err" in res.diagnostic, res.diagnostic
print("wire faults classified at l2 (corrupt -> non-finite, "
      "truncated -> rel err)")

# ---- evaluator hardening: a wedged candidate quarantines at the deadline
# and the evaluator keeps serving --------------------------------------------
wedge = get_workload("kv_transfer")
orig_build = wedge.build


def wedged_build(d, mesh):
    if d.placement == "TILE_FUSED":
        def hang(*xs):
            time.sleep(60.0)          # wedges the trace
            return orig_build(d, mesh)(*xs)
        return hang
    return orig_build(d, mesh)


wedge.build = wedged_build
ev = CascadeEvaluator(wedge, mesh2, hw2, timeout_s=2.0)
t0 = time.perf_counter()
res = ev.evaluate(Candidate(directive=FLUX))
assert res.quarantined and res.score == 0.0, res.diagnostic
assert time.perf_counter() - t0 < 30.0
assert len(ev.quarantine_report()) == 1
res = ev.evaluate(Candidate(
    directive=Directive("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT",
                        contexts=2)))
assert res.level == 3, (res.level, res.diagnostic)
print("wedged candidate quarantined "
      f"({ev.quarantine_report()[0]['elapsed_s']:.1f}s); evaluator survived")

with open(A.out, "w") as f:
    json.dump(bench, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"wrote {A.out}")
print("ALL OK")
