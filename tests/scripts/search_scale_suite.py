"""Scaled-search suite at simulated ranks (default 4): the executable
acceptance gate of the batched cascade + warm-start store (docs/search.md,
ROADMAP open item 3).

Covers:
  * batched ring_attention search — a real 4-rank interpret-mode workload
    run through ``slow_path(batched=True)`` must produce the identical
    ``db.history()`` and byte-identical telemetry payload as the
    sequential run (the parity contract, here at multi-rank scale);
  * warm-start economics on gemm_allgather — a cold search persists its
    store; the warm resume must serve every stored directive from cache
    (zero re-evaluations) and reach the cold run's best score in at most
    half the fresh evaluations the cold run needed, with coverage resuming
    at least where it left off;
  * cross-workload transfer payoff — the tuned gemm_allgather store seeds
    a moe_dispatch search via ``transfer_seeds`` (tile-knob alias mapping
    + validity repair); the transferred search must reach the cold-start
    moe_dispatch best score in at most half the fresh evaluations the
    cold search needed;
  * the deterministic ``BENCH_search_scale.json`` artifact recording all
    of the above — wall timings excluded, so the checked-in copy must
    match regeneration byte for byte (CI staleness gate).
"""
import argparse
import json
import pathlib

import jax

from repro.compat import make_mesh
from repro.core import (CandidateDB, SlowPathConfig, directive_key,
                        extract_hardware_context, fast_path, slow_path)
from repro.core.cascade import CascadeEvaluator
from repro.workloads import get_workload

args = argparse.ArgumentParser()
args.add_argument("--out", default="BENCH_search_scale.json",
                  help="path for the search-scale benchmark artifact")
A = args.parse_args()

n_dev = len(jax.devices())
assert n_dev >= 4, f"suite needs >=4 simulated ranks, got {n_dev}"
mesh = make_mesh((4,), ("x",))
hw = extract_hardware_context(mesh)
bench = {"schema": "bench-search-scale/v1", "n_dev": 4}

# ---------------------------------------------- batched parity at 4 ranks
ring = get_workload("ring_attention", n_dev=4, BH=4, seq=512, hd=64)
ring_seed = fast_path(ring, mesh, hw)
ring_cfg = SlowPathConfig(islands=2, generations=3, seed=2)
seq = slow_path(ring_seed, mesh, hw, ring_cfg)
bat = slow_path(ring_seed, mesh, hw, ring_cfg, batched=True, eval_workers=3)
assert seq.history == bat.history, "batched ring search diverged from sequential"
p_seq = json.dumps(seq.telemetry.payload(), sort_keys=True)
p_bat = json.dumps(bat.telemetry.payload(), sort_keys=True)
assert p_seq == p_bat, "batched telemetry payload diverged"
assert bat.best.score >= bat.seed_score
print(f"ring_attention batched parity ok ({len(bat.history)} evals, "
      f"best {bat.best.score:.2f})")
bench["ring_parity"] = {"evals": len(bat.history),
                        "best_score": bat.best.score,
                        "seed_score": bat.seed_score,
                        "history_equal": True, "payload_equal": True}


# ------------------------------------------------- warm-start economics
class CountingEvaluator(CascadeEvaluator):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.evaluated = []

    def _evaluate(self, cand, publish=True):
        self.evaluated.append(directive_key(cand.directive))
        return super()._evaluate(cand, publish=publish)


gemm = get_workload("gemm_allgather", n_dev=4, M=2048, K=2048, N=2048)
gemm_seed = fast_path(gemm, mesh, hw)
gemm_cfg = SlowPathConfig(islands=2, generations=4, seed=1)
store = "/tmp/cuco_search_scale_store.json"
cold = slow_path(gemm_seed, mesh, hw, gemm_cfg, batched=True, save_to=store)
cold_best = cold.best.score
# fresh evaluations the cold run needed before first reaching its best
cold_evals_to_best = next(i + 1 for i, r in enumerate(cold.db.records)
                          if r.score >= cold_best)

ev = CountingEvaluator(gemm, mesh, hw)
warm = slow_path(gemm_seed, mesh, hw, gemm_cfg, evaluator=ev,
                 warm_start=store)
saved_keys = {directive_key(r.directive) for r in cold.db.records}
assert not (set(ev.evaluated) & saved_keys), \
    "warm start re-evaluated a cached directive"
warm_fresh_to_best = 0
for r in warm.db.records:
    if not r.cached:
        warm_fresh_to_best += 1
    if r.score >= cold_best:
        break
else:
    raise AssertionError("warm start never reached the cold-start best")
assert warm_fresh_to_best <= cold_evals_to_best // 2, (
    f"warm start needed {warm_fresh_to_best} fresh evals to reach the "
    f"cold best; cold needed {cold_evals_to_best} (payoff must be >=2x)")
assert warm.archive.coverage() >= cold.archive.coverage()
sc = warm.telemetry.scale
assert sc["warm_start"] and sc["cache_hits"] > 0
print(f"gemm_allgather warm start ok (cold {cold_evals_to_best} evals to "
      f"best, warm {warm_fresh_to_best} fresh; {sc['cache_hits']} cache hits)")
bench["warm_start"] = {
    "cold_evals_to_best": cold_evals_to_best,
    "warm_fresh_evals_to_best": warm_fresh_to_best,
    "cache_hits": sc["cache_hits"],
    "cold_best_score": cold_best,
    "warm_best_score": warm.best.score,
    "coverage_saved": cold.archive.coverage(),
    "coverage_resumed": warm.archive.coverage(),
}

# the persisted store round-trips exactly
db2 = CandidateDB.load(store)
assert db2.history() == cold.db.history()
print(f"store round-trip ok ({len(db2.records)} records)")

# -------------------------------------------------- cross-workload transfer
moe = get_workload("moe_dispatch", n_dev=4, tokens_per_rank=1024, d=256,
                   f=512)
moe_seed = fast_path(moe, mesh, hw)
moe_cfg = SlowPathConfig(islands=3, generations=3, seed=2)
moe_cold = slow_path(moe_seed, mesh, hw, moe_cfg, batched=True)
moe_cold_best = moe_cold.best.score
moe_cold_to_best = next(i + 1 for i, r in enumerate(moe_cold.db.records)
                        if r.score >= moe_cold_best)

xfer = slow_path(moe_seed, mesh, hw, moe_cfg, batched=True,
                 warm_start=store)
xs = xfer.telemetry.scale
assert xs["warm_start"] and xs["transferred_seeds"] > 0, xs
assert xs["cache_hits"] == 0, "a cached score crossed a fingerprint boundary"
assert xfer.best.score >= xfer.seed_score
transfer_gen0 = [r for r in xfer.db.records
                 if r.gen == 0 and r.mutation == "transfer-seed"]
assert transfer_gen0, "no transferred elite seeded generation zero"
# the acceptance bar: the transferred search reaches the cold-start best
# in at most half the fresh evaluations the cold search needed
xfer_fresh_to_best = 0
for r in xfer.db.records:
    if not r.cached:
        xfer_fresh_to_best += 1
    if r.score >= moe_cold_best:
        break
else:
    raise AssertionError("transferred search never reached the cold best")
assert xfer_fresh_to_best <= moe_cold_to_best // 2, (
    f"transferred moe_dispatch search needed {xfer_fresh_to_best} fresh "
    f"evals to reach the cold best; cold needed {moe_cold_to_best} "
    "(payoff must be >=2x)")
print(f"gemm_allgather -> moe_dispatch transfer ok "
      f"({xs['transferred_seeds']} seeds mapped, {len(transfer_gen0)} "
      f"seeded; cold {moe_cold_to_best} evals to best, transferred "
      f"{xfer_fresh_to_best} fresh)")
bench["transfer"] = {
    "transferred_seeds": xs["transferred_seeds"],
    "gen0_transfer_seeds": len(transfer_gen0),
    "gen0_transfer_ok": sum(1 for r in transfer_gen0
                            if r.result and r.result.ok),
    "cold_evals_to_best": moe_cold_to_best,
    "transfer_fresh_evals_to_best": xfer_fresh_to_best,
    "cold_best_score": moe_cold_best,
    "best_score": xfer.best.score,
    "seed_score": xfer.seed_score,
}

# the checked-in search artifact rode the same schema bump: the byte-level
# staleness gate lives in telemetry_suite; here we pin the schema + the
# scale section so a stale v1 artifact fails fast in this job too
repo_bench = pathlib.Path(__file__).resolve().parents[2] / "BENCH_search.json"
search_payload = json.loads(repo_bench.read_text())
assert search_payload["schema"] == "bench-search/v2", \
    "BENCH_search.json is stale — re-run telemetry_suite.py and commit"
assert set(search_payload["scale"]) == {"warm_start", "cache_hits",
                                        "transferred_seeds"}
print("BENCH_search.json schema/scale section ok")

with open(A.out, "w") as f:
    json.dump(bench, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"wrote {A.out}")
print("ALL OK")
