"""CUCo end-to-end: analyzer -> fast path -> slow path on two workloads;
search invariants (archive dominance, novelty, monotone best-so-far)."""
from repro.core import (SlowPathConfig, extract_hardware_context,
                        fast_path, slow_path)
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload

mesh = make_mesh((4,), ("x",))
hw = extract_hardware_context(mesh)

for wname, kw in [("gemm_allgather", dict(n_dev=4, M=4096, K=4096, N=4096)),
                  ("moe_dispatch", dict(n_dev=4, tokens_per_rank=512, d=128,
                                        f=256, skew=3.0)),
                  # ring workload: the search refines the kernelized ring
                  # points through the kv_chunk/contexts tunables
                  ("ring_attention", dict(n_dev=4, BH=4, seq=512, hd=64))]:
    w = get_workload(wname, **kw)
    seed = fast_path(w, mesh, hw)
    assert seed.candidate.result.ok
    assert seed.graph.nodes, "analyzer must find the host collectives"
    res = slow_path(seed, mesh, hw,
                    SlowPathConfig(islands=2, generations=6, seed=1))
    assert res.best is not None and res.best.result.ok
    assert res.best.score >= res.seed_score * 0.999, (
        wname, res.best.score, res.seed_score)
    # archive dominance invariant: each cell's elite is the best of its kind
    for b, elite in res.archive.cells.items():
        same = [r for r in res.db.records
                if r.directive.behavior == b and r.result and r.result.ok]
        assert elite.score == max(c.score for c in same)
    # best-so-far series is monotone
    series = res.best_per_generation()
    assert all(series[i][1] <= series[i + 1][1]
               for i in range(len(series) - 1))
    # novelty: no duplicate directives in the db
    seen = [r.directive for r in res.db.records]
    assert len({d for d in seen}) == len(seen), "novelty filter violated"
    print(wname, "search ok: %.1f -> %.1f" % (res.seed_score, res.best.score))

print("ALL OK")
