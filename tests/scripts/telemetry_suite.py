"""Observability suite at simulated ranks (default 4): the executable
acceptance gate of the tracing + telemetry layer (core/trace.py,
core/telemetry.py).

Covers:
  * a short single-island slow-path run with full cascade telemetry — one
    :class:`EvalRecord` per evaluated candidate (success, failure, and the
    quarantine/error hardening paths), each JSON round-trippable;
  * the per-generation / per-island / per-mutation series aggregate
    consistently, and the deterministic ``BENCH_search.json`` artifact is
    (re)generated at ``--out`` — the checked-in copy must match what this
    suite produces;
  * every workload's FLUX point renders a Perfetto-loadable
    ``schedule_timeline`` whose critical path equals ``analytic_cost``
    within 1e-6, plus a degraded-membership render (``--trace-dir`` dumps
    the traces for manual ui.perfetto.dev inspection);
  * the :class:`ScheduleProbe` observed-vs-modeled check against the real
    interpret-mode gemm_allgather kernel: the DMA issue/wait order the
    kernel body actually performs matches the trace-time
    ``CollectiveSchedule`` the cost model charged.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core import extract_hardware_context, fast_path, slow_path
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import EXPERT_SYSTEMS
from repro.core.schedule import make_broadcast_schedule
from repro.core.slow_path import SlowPathConfig
from repro.core.telemetry import EvalRecord
from repro.core.trace import ScheduleProbe, schedule_timeline, validate_trace
from repro.kernels.gemm_allgather import gemm_allgather
from repro.kernels.ref import gemm_allgather_ref
from repro.workloads import get_workload

args = argparse.ArgumentParser()
args.add_argument("--out", default="BENCH_search.json",
                  help="path for the search-telemetry benchmark artifact")
args.add_argument("--trace-dir", default=None,
                  help="directory to dump one Perfetto trace per workload")
A = args.parse_args()

FLUX = EXPERT_SYSTEMS["FLUX"]
mesh = make_mesh((4,), ("x",))
hw = extract_hardware_context(mesh)

# ---- 1-island search with telemetry ---------------------------------------
# migration needs a second island, so keep migration_every past the horizon
cfg = SlowPathConfig(islands=1, generations=6, migration_every=7, seed=1)
w = get_workload("gemm_allgather", n_dev=4, M=4096, K=4096, N=4096)
seed = fast_path(w, mesh, hw)
res = slow_path(seed, mesh, hw, cfg)
tel = res.telemetry
assert tel is not None and tel.workload == w.name
assert len(tel.records) == len(res.db.records), (
    len(tel.records), len(res.db.records))
for rec in tel.records:                     # every row JSON round-trips
    assert EvalRecord.from_json(rec.to_json()) == rec
gens = tel.generation_series()
assert [g["gen"] for g in gens] == list(range(cfg.generations + 1))
assert all(g["archive_coverage"] is not None for g in gens)
assert sum(g["evals"] for g in gens) == len(tel.records)
ok_records = [r for r in tel.records if r.level >= 3]
assert ok_records, "the search must land level-3 candidates"
assert all(r.t_model_ms is not None and "l3" in r.levels_s
           for r in ok_records)
isl = tel.island_series()
assert [i["island"] for i in isl] == [0]
muts = {m["mutation"]: m for m in tel.mutation_stats()}
assert "island-seed" in muts and muts["island-seed"]["wins"] >= 1
assert sum(m["wins"] for m in muts.values()) >= 1
print(f"search telemetry ok: {len(tel.records)} records over "
      f"{cfg.generations} generations, best={tel.payload()['totals']['best_score']:.2f}")

# ---- the BENCH_search.json artifact (deterministic, diff-stable) ----------
meta = {"islands": cfg.islands, "generations": cfg.generations,
        "seed": cfg.seed, "shape": "n_dev=4 M=4096 K=4096 N=4096"}
tel.write(A.out, meta=meta)
payload = json.loads(open(A.out).read())
assert payload["schema"] == "bench-search/v2"
assert payload["best"]["score"] == payload["totals"]["best_score"]
assert "Infinity" not in open(A.out).read()
print(f"wrote {A.out} ({payload['totals']['evals']} evals, "
      f"{payload['totals']['ok']} ok)")

# ---- hardened-path records: quarantine + evaluator error carry rows -------
wedge = get_workload("kv_transfer")
orig_build = wedge.build
wedge.build = lambda d, m: (lambda *xs: time.sleep(60.0))
mesh2 = make_mesh((2,), ("x",), devices=jax.devices()[:2])
ev = CascadeEvaluator(wedge, mesh2, extract_hardware_context(mesh2),
                      timeout_s=1.5)
qres = ev.evaluate(Candidate(directive=FLUX))
assert qres.quarantined and qres.record is not None
assert qres.record.quarantined and "quarantine" in qres.record.levels_s
assert ev.quarantine_report()[0]["record"]["quarantined"] is True
wedge.build = orig_build
print("quarantine path carries an EvalRecord "
      f"(elapsed {qres.record.elapsed_s:.1f}s)")

# ---- per-workload FLUX timelines: Perfetto-valid, critical path == l3 -----
for name, kw in (("gemm_allgather", {}), ("moe_dispatch", {}),
                 ("ring_attention", {}), ("kv_transfer", {})):
    wl = get_workload(name, **kw)
    tl = schedule_timeline(wl, FLUX, hw)
    n_ev = validate_trace(tl.to_dict())
    expect = wl.analytic_cost(FLUX, hw)
    assert abs(tl.critical_path_s - expect) < 1e-6, (
        name, tl.critical_path_s, expect)
    dtl = schedule_timeline(wl, FLUX, hw,
                            live_ranks=tuple(range(wl.n_dev - 1)))
    assert dtl.degraded
    validate_trace(dtl.to_dict())
    if A.trace_dir:
        os.makedirs(A.trace_dir, exist_ok=True)
        tl.write(os.path.join(A.trace_dir, f"timeline_{name}.json"), indent=1)
    print(f"timeline {name}: {n_ev} events, critical path "
          f"{tl.critical_path_s*1e3:.3f} ms == analytic_cost")

# ---- observed-vs-modeled: the probe inside the real kernel ----------------
key = jax.random.PRNGKey(5)
n, M_l, K, N = 4, 64, 64, 64
a = jax.random.normal(key, (n, M_l, K), jnp.float32)
b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
ref = gemm_allgather_ref(a, b)
for fused, counter, contexts in ((True, True, 2), (True, False, 1),
                                 (False, False, 2)):
    probe = ScheduleProbe()
    out = gemm_allgather(a, b, mesh, tile_m=32, fused=fused, counter=counter,
                         contexts=contexts, probe=probe)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3)
    sched = make_broadcast_schedule(n, M_l, 32, fused)
    summary = probe.check(sched, contexts, counter=counter)
    print(f"probe fused={fused} counter={counter} contexts={contexts}: "
          f"{summary['rounds']} rounds, max depth {summary['max_depth']}, "
          f"{summary['recv_waits']} recv waits — observed == modeled")

print("ALL OK")
