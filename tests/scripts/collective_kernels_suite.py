"""gemm_allgather + kv_shuttle kernels at simulated ranks (default 4).

Covers the FLUX-grade gemm_allgather acceptance criteria that need devices:
  * the TILE_FUSED + COUNTER (FLUX) point and the DEFERRED kernel point
    evaluate to l3 through the full cascade (l1 build/lower -> l2
    interpret-mode verify -> l3 analytic model);
  * kernel numerics match ``gemm_allgather_ref`` for the fused and deferred
    paths across tile_m values (including a non-divisor that the sanitizer
    must repair), completion realizations, and send-window depths;
  * the kv_shuttle variants stay green (race detector for the K->V chain).

``--n-dev`` reshapes the suite (the executable counterpart of the fig6
analytic sweep at wider meshes — ROADMAP open item, the same budget-capped
pattern as moe_dispatch_suite). Interpret mode is orders of magnitude
slower than hardware, so any ``--n-dev`` other than the default 4 runs a
reduced sweep: tiny shapes, FLUX + DEFERRED cascades to l3, one numerics
verify per broadcast path.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extract_hardware_context
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import EXPERT_SYSTEMS, Directive
from repro.kernels.gemm_allgather import gemm_allgather
from repro.kernels.kv_shuttle import kv_shuttle
from repro.kernels.ref import gemm_allgather_ref, kv_shuttle_ref
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload

D = Directive
args = argparse.ArgumentParser()
args.add_argument("--n-dev", type=int, default=4,
                  help="mesh size (must match the simulated device count)")
N_DEV = args.parse_args().n_dev
key = jax.random.PRNGKey(3)

if N_DEV != 4:
    # ---- budget-capped broadcast sweep at a non-default rank count ------
    mesh = make_mesh((N_DEV,), ("x",))
    w = get_workload("gemm_allgather", n_dev=N_DEV, M=4096, K=4096, N=4096)
    hw = extract_hardware_context(mesh)
    ev = CascadeEvaluator(w, mesh, hw,
                          verify_inputs=w.example_inputs(key, mesh, M_l=64))

    flux = EXPERT_SYSTEMS["FLUX"]
    res_f = ev.evaluate(Candidate(directive=flux))
    assert res_f.level == 3, (res_f.level, res_f.diagnostic)
    print(f"cascade gemm_allgather flux l3 ok at {N_DEV} ranks "
          f"({res_f.diagnostic})")
    deferred = D("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL", "KERNEL",
                 "PER_PEER", "RELEASE", 2)
    res_d = ev.evaluate(Candidate(directive=deferred))
    assert res_d.level == 3, (res_d.level, res_d.diagnostic)
    # at wide wire-bound meshes the per-peer round overhead of the DEFERRED
    # slab path outgrows its launch savings and flux models within noise of
    # it — the wide-mesh gate is "the FLUX point beats host"; the strict
    # flux < deferred < host ordering is asserted at the 4-rank shape
    host = w.analytic_cost(D("XLA_COLLECTIVE", placement="DEFERRED"), hw)
    assert res_f.t_model_ms < host * 1e3
    print(f"cascade gemm_allgather deferred l3 ok at {N_DEV} ranks "
          "(flux beats host)")

    # one numerics verify per broadcast path (fused COUNTER + deferred)
    a = jax.random.normal(key, (N_DEV, 64, 64), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (64, 64), jnp.float32)
    ref = gemm_allgather_ref(a, b)
    for fused, counter in [(True, True), (False, False)]:
        out = gemm_allgather(a, b, mesh, tile_m=32, fused=fused,
                             counter=counter, contexts=2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4)
    print(f"gemm_allgather numerics ok at {N_DEV} ranks")
    print("ALL OK")
    raise SystemExit(0)

mesh4 = make_mesh((4,), ("x",))

# ---- cascade: FLUX (TILE_FUSED + COUNTER) and DEFERRED kernel points
# evaluate to l3 at 4 ranks under interpret mode
w = get_workload("gemm_allgather", n_dev=4, M=4096, K=4096, N=4096)
hw = extract_hardware_context(mesh4)
ev = CascadeEvaluator(w, mesh4, hw)

flux = EXPERT_SYSTEMS["FLUX"]
res_f = ev.evaluate(Candidate(directive=flux))
assert res_f.level == 3, (res_f.level, res_f.diagnostic)
assert res_f.score > 0
print(f"cascade gemm_allgather flux l3 ok ({res_f.diagnostic})")

deferred = D("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL", "KERNEL",
             "PER_PEER", "RELEASE", 2)
res_d = ev.evaluate(Candidate(directive=deferred))
assert res_d.level == 3, (res_d.level, res_d.diagnostic)
host_cost = w.analytic_cost(D("XLA_COLLECTIVE", placement="DEFERRED"), hw)
assert res_f.t_model_ms < res_d.t_model_ms < host_cost * 1e3
print("cascade gemm_allgather deferred l3 ok (flux < deferred < host)")

# a slow-path diff patch may propose any TUNABLES grid value — including
# one that does not divide M_l; the sanitizer must keep the evaluator alive
res_bad = ev.evaluate(Candidate(directive=flux.with_tunable("tile_m", 96)))
assert res_bad.level == 3, (res_bad.level, res_bad.diagnostic)
print("cascade gemm_allgather non-divisor tile_m ok (sanitized)")

# ---- kernel numerics: fused (SIGNAL + COUNTER) and deferred paths across
# shapes and >= 2 tile_m values each, plus window depths
for (M_l, K, N, tm) in [(128, 64, 128, 32), (256, 128, 256, 128),
                        (64, 256, 128, 64)]:
    a = jax.random.normal(key, (4, M_l, K), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    ref = gemm_allgather_ref(a, b)
    for fused, counter, contexts in [(True, True, 1), (True, True, 2),
                                     (True, False, 2), (False, False, 1),
                                     (False, False, 4)]:
        out = gemm_allgather(a, b, mesh4, tile_m=tm, fused=fused,
                             counter=counter, contexts=contexts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4,
            err_msg=str((M_l, K, N, tm, fused, counter, contexts)))
print("gemm_allgather numerics ok (fused/counter/deferred x tile_m)")

mesh2 = make_mesh((2,), ("x",))
for (T, d, dk) in [(64, 128, 64), (128, 256, 128)]:
    x_real = jax.random.normal(key, (T, d), jnp.float32)
    x = jnp.stack([x_real, jnp.zeros_like(x_real)])
    wk = jax.random.normal(jax.random.fold_in(key, 2), (d, dk), jnp.float32)
    wv = jax.random.normal(jax.random.fold_in(key, 3), (d, dk), jnp.float32)
    kr, vr = kv_shuttle_ref(x_real, wk, wv)
    for chained in (True, False):
        ko, vo = kv_shuttle(x, wk, wv, mesh2, chained=chained)
        np.testing.assert_allclose(np.asarray(ko[1]), np.asarray(kr),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(vo[1]), np.asarray(vr),
                                   atol=2e-4, rtol=2e-4)
print("kv_shuttle ok")

print("ALL OK")
