"""gemm_allgather + kv_shuttle kernels: variants, shapes, race detector."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gemm_allgather import gemm_allgather
from repro.kernels.kv_shuttle import kv_shuttle
from repro.kernels.ref import gemm_allgather_ref, kv_shuttle_ref
from repro.launch.mesh import make_mesh

mesh4 = make_mesh((4,), ("x",))
key = jax.random.PRNGKey(3)

for (M_l, K, N, tm) in [(128, 64, 128, 32), (256, 128, 256, 128),
                        (64, 256, 128, 64)]:
    a = jax.random.normal(key, (4, M_l, K), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    ref = gemm_allgather_ref(a, b)
    for fused in (True, False):
        out = gemm_allgather(a, b, mesh4, tile_m=tm, fused=fused)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4,
                                   err_msg=str((M_l, K, N, tm, fused)))

mesh2 = make_mesh((2,), ("x",))
for (T, d, dk) in [(64, 128, 64), (128, 256, 128)]:
    x_real = jax.random.normal(key, (T, d), jnp.float32)
    x = jnp.stack([x_real, jnp.zeros_like(x_real)])
    wk = jax.random.normal(jax.random.fold_in(key, 2), (d, dk), jnp.float32)
    wv = jax.random.normal(jax.random.fold_in(key, 3), (d, dk), jnp.float32)
    kr, vr = kv_shuttle_ref(x_real, wk, wv)
    for chained in (True, False):
        ko, vo = kv_shuttle(x, wk, wv, mesh2, chained=chained)
        np.testing.assert_allclose(np.asarray(ko[1]), np.asarray(kr),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(vo[1]), np.asarray(vr),
                                   atol=2e-4, rtol=2e-4)
print("ALL OK")
