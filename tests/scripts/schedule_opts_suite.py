"""The §Perf schedule knobs must be semantics-preserving: seq-parallel
prefill, SP residuals, loss chunking, and MoE overlap/quantize produce the
same numbers (quantize within int8 tolerance) as the baseline schedule."""
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, reduced
from repro.dist.sharding import Rules, sanitize_specs
from repro.compat import set_mesh
from repro.launch.mesh import make_mesh
from repro.models import (StepOptions, init_params, param_specs,
                          prefill_step, train_loss)

mesh = make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)

for arch in ("recurrentgemma-9b", "llama3.2-1b"):
    cfg = reduced(get_arch(arch), dtype="float32")
    params = init_params(key, cfg)
    B, S = 8, 64
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), key)

    rules_t = Rules(mesh, "train")
    specs = sanitize_specs(param_specs(cfg, rules_t), shapes, mesh)
    with set_mesh(mesh):
        pl_ = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda s: isinstance(s, P)))
        base = float(jax.jit(lambda p, b: train_loss(
            p, b, cfg, rules_t, StepOptions()))(pl_, batch))
        spres = float(jax.jit(lambda p, b: train_loss(
            p, b, cfg, rules_t, StepOptions(sp_residuals=True)))(pl_, batch))
        chunk = float(jax.jit(lambda p, b: train_loss(
            p, b, cfg, rules_t, StepOptions(loss_chunk=16)))(pl_, batch))
        np.testing.assert_allclose(base, spres, rtol=1e-4, err_msg=arch)
        np.testing.assert_allclose(base, chunk, rtol=1e-4, err_msg=arch)

        rules_p = Rules(mesh, "prefill")
        pb = {"tokens": batch["tokens"]}
        lo0, _ = jax.jit(lambda p, b: prefill_step(
            p, b, cfg, rules_p, seq_len=S, opts=StepOptions()))(pl_, pb)
        lo1, _ = jax.jit(lambda p, b: prefill_step(
            p, b, cfg, rules_p, seq_len=S,
            opts=StepOptions(seq_parallel=True)))(pl_, pb)
        np.testing.assert_allclose(np.asarray(lo0), np.asarray(lo1),
                                   atol=5e-3, rtol=5e-3, err_msg=arch)
    print(arch, "ok")
print("ALL OK")
