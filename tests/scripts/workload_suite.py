"""Every workload x a spread of valid directives verifies against the oracle
(semantics-preserving builders — the cascade l2 invariant)."""
import jax
import numpy as np

from repro.core.design_space import Directive
from repro.workloads import get_workload
from repro.launch.mesh import make_mesh

mesh4 = make_mesh((4,), ("x",))
mesh2 = make_mesh((2,), ("x",))
key = jax.random.PRNGKey(5)
D = Directive


def check(wname, mesh, directives, tol=2e-3, **kw):
    w = get_workload(wname, **kw)
    inputs = w.example_inputs(key, mesh)
    ref = w.reference(*inputs)
    host = jax.jit(w.host_baseline(mesh))(*inputs)
    for got, exp in zip(jax.tree.leaves(host), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=tol, rtol=tol,
                                   err_msg=f"{wname} host baseline")
    for d in directives:
        out = jax.jit(w.build(d, mesh))(*inputs)
        t = 0.1 if d.tunable("wire_i8", 0) else tol
        for got, exp in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(exp), atol=t, rtol=t,
                err_msg=f"{wname} {d.backend}/{d.placement}")
    print(wname, "ok")


check("ring_attention", mesh4, [
    D("XLA_COLLECTIVE", placement="STREAM_SPLIT"),
    D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", contexts=2),
    D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", ordering="ACQREL", contexts=2),
    D("PALLAS_RDMA", "BARRIER", "DEFERRED"),
    D("PALLAS_RDMA", "COUNTER", "TILE_FUSED", granularity="PER_TILE",
      contexts=2),
], n_dev=4, BH=4, seq=512, hd=64)

check("moe_dispatch", mesh4, [
    D("XLA_COLLECTIVE", placement="STREAM_SPLIT"),
    D("XLA_COLLECTIVE", placement="DEFERRED"),
    D("XLA_COLLECTIVE", placement="STREAM_SPLIT").with_tunable("wire_i8", 1),
    # device-initiated kernel (DeepEP analogue): Table-3 NVL point, the
    # pipelined tight-dispatch refinement, and its int8-wire variant
    D("PALLAS_RDMA", "BARRIER", "DEFERRED", "LOCAL", "KERNEL",
      "PER_PEER", "RELEASE", 1),
    D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
      "PER_PEER", "ACQUIRE", 2),
    D("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL", "GRID_STEP",
      "PER_PEER", "ACQUIRE", 2).with_tunable("wire_i8", 1),
], n_dev=4, tokens_per_rank=256, d=128, f=256, skew=3.0)

for skew in (2.0, 5.0):
    check("moe_dispatch", mesh4,
          [D("XLA_COLLECTIVE", placement="STREAM_SPLIT")],
          n_dev=4, tokens_per_rank=128, d=64, f=128, skew=skew)

check("kv_transfer", mesh2, [
    D("XLA_COLLECTIVE", placement="STREAM_SPLIT"),
    D("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT"),
    D("PALLAS_RDMA", "SIGNAL", "DEFERRED"),
    D("PALLAS_RDMA", "SIGNAL", "STREAM_SPLIT", ordering="ACQREL"),
    # per-tile fused K/V GEMM + send chain (the FLUX shuttle point)
    D("PALLAS_RDMA", "COUNTER", "TILE_FUSED", granularity="PER_TILE",
      contexts=2).with_tunable("kv_chunk", 32),
])

check("gemm_allgather", mesh4, [
    D("XLA_COLLECTIVE", placement="STREAM_SPLIT", tunables=(("chunks", 4),)),
    D("XLA_COLLECTIVE", placement="STREAM_SPLIT", tunables=(("chunks", 2),)),
    D("PALLAS_RDMA", "SIGNAL", "TILE_FUSED", tunables=(("tile_m", 32),)),
    D("PALLAS_RDMA", "SIGNAL", "TILE_FUSED", tunables=(("tile_m", 64),)),
    D("PALLAS_RDMA", "BARRIER", "DEFERRED"),
], n_dev=4)

print("ALL OK")
