"""l0 sanitizer suite at 4 simulated ranks: the executable acceptance gate
of the schedule-verification tier (static-analysis ISSUE).

Covers:
  * the full lint sweep — every (workload, expert-system) point passes l0
    (vacuous for the XLA points), every seeded mutation class is rejected
    with its class-specific first diagnostic (``tools/schedule_lint.py``
    as a library);
  * the economics claim behind wiring the verifier in *ahead* of l1/l2:
    the mean wall-clock of an l0 rejection over the mutation corpus must
    be under 10% of the mean l2 interpret-verify cost it avoids (measured
    from real ``CascadeEvaluator`` runs over kernelized points at reduced
    shapes — ``EvalRecord.levels_s['l2']``);
  * the ``BENCH_verify.json`` artifact at ``--out``.  Wall-times are
    machine-dependent, so unlike BENCH_search.json the gate is the
    *ratio* assert, not byte equality of the regenerated file.
"""
import argparse
import json
import pathlib
import statistics
import sys
import time

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

from tools.schedule_lint import lint_mutations, lint_points  # noqa: E402

from repro.core import extract_hardware_context  # noqa: E402
from repro.core.cascade import Candidate, CascadeEvaluator  # noqa: E402
from repro.core.design_space import EXPERT_SYSTEMS  # noqa: E402
from repro.core.verify import mutation_corpus  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.workloads import get_workload  # noqa: E402

args = argparse.ArgumentParser()
args.add_argument("--out", default="BENCH_verify.json",
                  help="path for the l0-vs-l2 economics artifact")
args.add_argument("--reps", type=int, default=5,
                  help="timing repetitions per mutation-corpus entry")
A = args.parse_args()

assert jax.device_count() >= 4, jax.device_count()
mesh = make_mesh((4,), ("x",))
hw = extract_hardware_context(mesh)

# ---- the lint sweep: clean points verified, mutations caught --------------
print("verify_suite: lint sweep (points + mutation corpus)")
prows, pfail = lint_points(quiet=True)
assert not pfail, pfail
n_ok = sum(r["status"] == "ok" for r in prows)
assert n_ok >= 10, prows
mrows, mfail = lint_mutations(quiet=True)
assert not mfail, mfail
print(f"  {n_ok} kernelized points clean, "
      f"{len(mrows)} mutation classes caught")

# ---- l0 rejection wall-time over the mutation corpus ----------------------
print("verify_suite: timing l0 rejections")
l0_rows = []
for entry in mutation_corpus():
    entry["run"]()                                     # warm (imports, JIT-free)
    times = []
    for _ in range(A.reps):
        t0 = time.perf_counter()
        rep = entry["run"]()
        times.append((time.perf_counter() - t0) * 1e3)
    assert not rep.ok and rep.errors[0].code == entry["expect"]
    l0_rows.append({"class": entry["cls"], "code": entry["expect"],
                    "l0_ms": statistics.mean(times)})
    print(f"  {entry['cls']:<24} {l0_rows[-1]['l0_ms']:7.2f} ms "
          f"[{entry['expect']}]")

# ---- the l2 interpret cost those rejections avoid -------------------------
# Real cascade runs over kernelized points at reduced shapes: the l2 level
# interpret-executes the actual Pallas kernel, which is the work a mutant
# schedule would have burned before failing the output compare.
print("verify_suite: measuring avoided l2 interpret cost")
POINTS = [
    ("moe_dispatch", dict(n_dev=4, tokens_per_rank=32, d=32, f=64),
     ("FLUX", "DeepEP (NVL)")),
    ("gemm_allgather", dict(n_dev=4, M=256, K=128, N=128),
     ("FLUX",)),
    ("ring_attention", dict(n_dev=4, BH=2, seq=256, hd=32),
     ("FLUX", "DeepEP (NVL)")),
]
l2_rows = []
for wname, kw, pnames in POINTS:
    w = get_workload(wname, **kw)
    ev = CascadeEvaluator(w, mesh, hw)
    for pname in pnames:
        d = EXPERT_SYSTEMS[pname]
        if w.check(d, hw):
            continue
        res = ev.evaluate(Candidate(directive=d))
        assert res.ok, (wname, pname, res.diagnostic)
        rec = res.record
        assert "l0" in rec.levels_s and "l2" in rec.levels_s
        l2_rows.append({"workload": wname, "point": pname,
                        "level": res.level,
                        "l0_ms": rec.levels_s["l0"] * 1e3,
                        "l2_ms": rec.levels_s["l2"] * 1e3})
        print(f"  {wname:<16} {pname:<14} l0 {l2_rows[-1]['l0_ms']:6.1f} ms"
              f"   l2 {l2_rows[-1]['l2_ms']:8.1f} ms")

# ---- the economics gate ---------------------------------------------------
l0_mean = statistics.mean(r["l0_ms"] for r in l0_rows)
l2_mean = statistics.mean(r["l2_ms"] for r in l2_rows)
ratio = l0_mean / l2_mean
payload = {
    "schema": "verify-bench/v1",
    "l0_rejections": l0_rows,
    "l2_interpret": l2_rows,
    "summary": {"l0_mean_ms": l0_mean, "l2_mean_ms": l2_mean,
                "ratio": ratio},
}
with open(A.out, "w") as f:
    json.dump(payload, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"verify_suite: l0 mean {l0_mean:.2f} ms vs l2 mean {l2_mean:.1f} ms "
      f"-> ratio {ratio:.4f} (gate < 0.1)")
assert ratio < 0.1, (l0_mean, l2_mean)
print("verify_suite: ALL OK ->", A.out)
