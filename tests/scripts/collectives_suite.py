"""dist.collectives helpers: compressed + hierarchical psum correctness."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import compressed_psum, hierarchical_psum
from repro.launch.mesh import make_mesh
from repro.compat import shard_map

mesh = make_mesh((2, 4), ("pod", "data"))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 16, 128), jnp.float32)


@functools.partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
                   out_specs=P(("pod", "data")), check_vma=False)
def ref_sum(xs):
    return jax.lax.psum(xs, ("pod", "data"))


@functools.partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
                   out_specs=P(("pod", "data")), check_vma=False)
def comp_sum(xs):
    return compressed_psum(xs, ("pod", "data"), group_size=8)


@functools.partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
                   out_specs=P(("pod", "data")), check_vma=False)
def hier_sum(xs):
    return hierarchical_psum(xs[0], pod_axis="pod", inner_axes=("data",))[None]


@functools.partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
                   out_specs=P(("pod", "data")), check_vma=False)
def hier_comp(xs):
    return hierarchical_psum(xs[0], pod_axis="pod", inner_axes=("data",),
                             compress_dcn=True)[None]


ref = ref_sum(x)
got = comp_sum(x)
rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
assert rel < 0.02, rel                      # int8-quantized: ~1% error
h = hier_sum(x)
np.testing.assert_allclose(np.asarray(h), np.asarray(ref), rtol=1e-5,
                           atol=1e-5)       # exact decomposition
hc = hier_comp(x)
rel2 = float(jnp.linalg.norm(hc - ref) / jnp.linalg.norm(ref))
assert rel2 < 0.02, rel2
print("ALL OK")
