"""Property tests for the trace-time round schedules of the device-initiated
kernels: the moe_dispatch permutation-round schedule (``DispatchSchedule``)
and the gemm_allgather broadcast-round schedule (``BroadcastSchedule``).

Invariants (docs/kernels.md — the lockstep contract the legacy 0.4.x pallas
interpreter enforces at runtime):
  * every (peer-offset, tile/microblock) edge appears exactly once;
  * the round order is total, deterministic, and rank-independent (lockstep:
    every rank issues the same DMA sequence);
  * the ``contexts``-deep send window never exceeds its cap and drains.
"""
import pytest

# property tests need hypothesis (optional test dep): skip, not error.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.gemm_allgather import (BroadcastSchedule,
                                          make_broadcast_schedule,
                                          sanitize_tile_m)
from repro.kernels.moe_dispatch import (make_schedule,
                                        sanitize_combine_tile)

# ----------------------------------------------------- strategy definitions

bcast_scheds = st.builds(
    lambda n, nt, tile_m, fused: make_broadcast_schedule(
        n, nt * tile_m, tile_m, fused),
    n=st.integers(1, 8), nt=st.integers(1, 16),
    tile_m=st.sampled_from((8, 32, 128)), fused=st.booleans())

disp_scheds = st.builds(
    lambda counts, B, tight: make_schedule(counts, B, tight),
    counts=st.lists(st.integers(0, 300), min_size=1, max_size=8),
    B=st.sampled_from((16, 64)), tight=st.booleans())

contexts = st.sampled_from((1, 2, 4))


# ------------------------------------------------------- broadcast schedule

@given(bcast_scheds)
@settings(max_examples=200, deadline=None)
def test_broadcast_every_edge_exactly_once(s):
    rounds = s.rounds
    assert len(rounds) == len(set(rounds)) == s.issued_rounds()
    if s.fused:
        assert set(rounds) == {(off, t) for off in range(1, s.n)
                               for t in range(s.nt)}
    else:
        assert set(rounds) == {(off, 0) for off in range(1, s.n)}
    # dense: every round moves rows_per_round rows, totalling the wire
    assert len(rounds) * s.rows_per_round == s.wire_rows()


@given(bcast_scheds)
@settings(max_examples=200, deadline=None)
def test_broadcast_order_total_and_tile_major(s):
    """Lockstep order: the round list is rank-independent by construction
    (no rank appears in it) and strictly ordered tile-major — tile t's
    broadcast issues before any tile t+1 round, so the fused kernel can
    overlap tile t+1's GEMM with tile t's wire."""
    rounds = s.rounds
    assert rounds == sorted(rounds, key=lambda r: (r[1], r[0]))
    assert rounds == s.rounds            # deterministic (a pure property)


@given(bcast_scheds)
@settings(max_examples=200, deadline=None)
def test_broadcast_ticks_cover_wire(s):
    # COUNTER ticks split the per-edge wait into per-tile waits: the tick
    # count times the tile rows covers exactly the inbound wire
    ticks = s.completion_ticks(counter=True)
    if s.fused:
        assert ticks * s.tile_m == (s.n - 1) * s.M_l
    assert s.completion_ticks(counter=False) == s.n - 1


@given(st.one_of(bcast_scheds, disp_scheds), contexts)
@settings(max_examples=200, deadline=None)
def test_send_window_never_exceeds_contexts(s, ctx):
    depths = s.send_window_depths(ctx)
    assert len(depths) == len(s.rounds)
    assert all(1 <= d <= max(1, ctx) for d in depths)
    # the window saturates once enough rounds exist (no artificial stall)
    if len(depths) >= ctx:
        assert max(depths, default=0) == min(ctx, len(depths))


# ----------------------------------------------------- dispatch (moe) rounds

@given(disp_scheds)
@settings(max_examples=200, deadline=None)
def test_dispatch_every_edge_exactly_once(s):
    rounds = s.rounds
    assert len(rounds) == len(set(rounds)) == s.n * s.b_max
    assert set(rounds) == {(off, j) for off in range(s.n)
                           for j in range(s.b_max)}


@given(disp_scheds)
@settings(max_examples=200, deadline=None)
def test_dispatch_wire_accounting_consistent(s):
    for rank in range(s.n):
        executed = s.executed_wire_tokens(rank)
        dummy = s.dummy_wire_tokens(rank)
        # lockstep rounds ship executed + dummy = the padded per-edge total
        assert executed + dummy == (s.n - 1) * s.b_max * s.block_tokens
        # the exact l3 credit never exceeds the block-rounded execution
        assert s.wire_tokens(rank) <= executed or not s.tight
    assert s.issued_rounds(elide_dummy=True) <= s.issued_rounds()


@given(st.integers(1, 256), st.integers(0, 512))
@settings(max_examples=200, deadline=None)
def test_sanitizers_return_divisors(B, req):
    ct = sanitize_combine_tile(req, B)
    assert B % ct == 0 and 1 <= ct <= B
    tm = sanitize_tile_m(req, B)
    assert B % tm == 0 and 1 <= tm <= B
