"""Property tests for the trace-time round schedules of the device-initiated
kernels — the three concrete builders of the ``CollectiveSchedule`` contract
in ``src/repro/core/schedule.py``: the moe_dispatch permutation-round
schedule (``DispatchSchedule``), the gemm_allgather broadcast-round schedule
(``BroadcastSchedule``), and the ring-rotation schedule (``RingSchedule``).

Invariants (docs/kernels.md — the lockstep contract the legacy 0.4.x pallas
interpreter enforces at runtime):
  * every (edge, tile/microblock/chunk) event appears exactly once;
  * the round order is total, deterministic, and rank-independent (lockstep:
    every rank issues the same DMA sequence);
  * the ``contexts``-deep send window never exceeds its cap and drains;
  * the sanitizers map any knob value to an exact divisor of the shape.
"""
import pytest

# property tests need hypothesis (optional test dep): skip, not error.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.schedule import (make_broadcast_schedule, make_ring_schedule,
                                 make_schedule, respill_counts,
                                 sanitize_combine_tile, sanitize_kv_chunk,
                                 sanitize_tile_m)

# ----------------------------------------------------- strategy definitions

bcast_scheds = st.builds(
    lambda n, nt, tile_m, fused: make_broadcast_schedule(
        n, nt * tile_m, tile_m, fused),
    n=st.integers(1, 8), nt=st.integers(1, 16),
    tile_m=st.sampled_from((8, 32, 128)), fused=st.booleans())

disp_scheds = st.builds(
    lambda counts, B, tight: make_schedule(counts, B, tight),
    counts=st.lists(st.integers(0, 300), min_size=1, max_size=8),
    B=st.sampled_from((16, 64)), tight=st.booleans())

ring_scheds = st.builds(
    lambda n, nc, kv_chunk, fused: make_ring_schedule(
        n, nc * kv_chunk, kv_chunk, fused),
    n=st.integers(1, 8), nc=st.integers(1, 16),
    kv_chunk=st.sampled_from((8, 32, 128)), fused=st.booleans())

contexts = st.sampled_from((1, 2, 4))


# ------------------------------------------------------- broadcast schedule

@given(bcast_scheds)
@settings(max_examples=200, deadline=None)
def test_broadcast_every_edge_exactly_once(s):
    rounds = s.rounds
    assert len(rounds) == len(set(rounds)) == s.issued_rounds()
    if s.fused:
        assert set(rounds) == {(off, t) for off in range(1, s.n)
                               for t in range(s.nt)}
    else:
        assert set(rounds) == {(off, 0) for off in range(1, s.n)}
    # dense: every round moves rows_per_round rows, totalling the wire
    assert len(rounds) * s.rows_per_round == s.wire_rows()


@given(bcast_scheds)
@settings(max_examples=200, deadline=None)
def test_broadcast_order_total_and_tile_major(s):
    """Lockstep order: the round list is rank-independent by construction
    (no rank appears in it) and strictly ordered tile-major — tile t's
    broadcast issues before any tile t+1 round, so the fused kernel can
    overlap tile t+1's GEMM with tile t's wire."""
    rounds = s.rounds
    assert rounds == sorted(rounds, key=lambda r: (r[1], r[0]))
    assert rounds == s.rounds            # deterministic (a pure property)


@given(bcast_scheds)
@settings(max_examples=200, deadline=None)
def test_broadcast_ticks_cover_wire(s):
    # COUNTER ticks split the per-edge wait into per-tile waits: the tick
    # count times the tile rows covers exactly the inbound wire
    ticks = s.completion_ticks(counter=True)
    if s.fused:
        assert ticks * s.tile_m == (s.n - 1) * s.M_l
    assert s.completion_ticks(counter=False) == s.n - 1


@given(st.one_of(bcast_scheds, disp_scheds, ring_scheds), contexts)
@settings(max_examples=200, deadline=None)
def test_send_window_never_exceeds_contexts(s, ctx):
    from repro.core.schedule import RingSchedule

    depths = s.send_window_depths(ctx)
    assert len(depths) == len(s.rounds)
    assert all(1 <= d <= max(1, ctx) for d in depths)
    # the window saturates once enough rounds exist (no artificial stall).
    # Ring kernels drain at every step boundary (the slot-credit
    # handshake), so their depth resets per step and saturates within one
    # step's rounds rather than across the whole list.
    if isinstance(s, RingSchedule):
        per_step = s.nc if s.fused else 1
        if s.steps:
            assert max(depths) == min(max(1, ctx), per_step)
    elif len(depths) >= ctx:
        assert max(depths, default=0) == min(ctx, len(depths))


# ----------------------------------------------------- dispatch (moe) rounds

@given(disp_scheds)
@settings(max_examples=200, deadline=None)
def test_dispatch_every_edge_exactly_once(s):
    rounds = s.rounds
    assert len(rounds) == len(set(rounds)) == s.n * s.b_max
    assert set(rounds) == {(off, j) for off in range(s.n)
                           for j in range(s.b_max)}


@given(disp_scheds)
@settings(max_examples=200, deadline=None)
def test_dispatch_wire_accounting_consistent(s):
    for rank in range(s.n):
        executed = s.executed_wire_tokens(rank)
        dummy = s.dummy_wire_tokens(rank)
        # lockstep rounds ship executed + dummy = the padded per-edge total
        assert executed + dummy == (s.n - 1) * s.b_max * s.block_tokens
        # the exact l3 credit never exceeds the block-rounded execution
        assert s.wire_tokens(rank) <= executed or not s.tight
    assert s.issued_rounds(elide_dummy=True) <= s.issued_rounds()


# ------------------------------------------------------ ring rotation rounds

@given(ring_scheds)
@settings(max_examples=200, deadline=None)
def test_ring_every_step_chunk_exactly_once(s):
    """Every (step, chunk) rotation event appears exactly once: n-1 shift
    steps, each split into nc chunks (fused) or one whole-shard round."""
    rounds = s.rounds
    assert len(rounds) == len(set(rounds)) == s.issued_rounds()
    if s.fused:
        assert set(rounds) == {(step, c) for step in range(s.steps)
                               for c in range(s.nc)}
    else:
        assert set(rounds) == {(step, 0) for step in range(s.steps)}
    # dense ring: every round moves rows_per_round rows of each rotated
    # tensor, totalling the (n-1)-shard wire
    assert len(rounds) * s.rows_per_round == s.wire_rows()


@given(ring_scheds)
@settings(max_examples=200, deadline=None)
def test_ring_order_total_and_step_major(s):
    """Lockstep order: rank-independent by construction and strictly
    step-major, chunk-ordered within a step — chunk c's send issues before
    chunk c+1's compute, and no step s+1 round precedes a step s round
    (the rotation's data dependence)."""
    rounds = s.rounds
    assert rounds == sorted(rounds)
    assert rounds == s.rounds            # deterministic (a pure property)


@given(ring_scheds)
@settings(max_examples=200, deadline=None)
def test_ring_ticks_cover_rotation(s):
    """The chunk-rotating kernels wait per-chunk semaphores whether ticks
    are interleaved (COUNTER) or drained up front (SIGNAL) — identical
    executed wait counts, so the model charges both the same; the tick
    count times the chunk rows covers exactly the rotated rows."""
    ticks = s.completion_ticks(counter=True)
    assert ticks == s.completion_ticks(counter=False)
    if s.fused:
        assert ticks * s.kv_chunk == s.steps * s.rows
    else:
        assert ticks == s.steps
    # a step has exactly nc chunk rounds (the drain boundary of the window)
    if s.fused and s.steps:
        step_rounds = [r for r in s.rounds if r[0] == 0]
        assert len(step_rounds) == s.nc


# ------------------------------------------- degraded-mode (fault) schedules

def draw_live(data, n):
    """A non-empty membership subset of an n-rank schedule."""
    return tuple(sorted(data.draw(
        st.sets(st.sampled_from(range(n)), min_size=1), label="live_ranks")))


@given(disp_scheds, contexts, st.data())
@settings(max_examples=200, deadline=None)
def test_dispatch_degrade_respills_and_keeps_contract(s, ctx, data):
    """degrade(live) respills the dead experts' tokens (conserving the
    total) into a smaller DispatchSchedule that re-satisfies the whole
    lockstep contract — live edges exactly once, total order, window cap."""
    live = draw_live(data, s.n)
    d = s.degrade(live)
    if len(live) == s.n:
        assert d is s
        return
    assert type(d) is type(s) and d.n == len(live)
    assert sum(d.counts) == sum(s.counts)          # token conservation
    assert all(c >= 0 for c in d.counts)
    assert (d.block_tokens, d.tight) == (s.block_tokens, s.tight)
    rounds = d.rounds
    assert len(rounds) == len(set(rounds)) == d.n * d.b_max
    assert set(rounds) == {(off, j) for off in range(d.n)
                           for j in range(d.b_max)}
    assert rounds == sorted(rounds)                # lockstep total order
    assert all(1 <= w <= max(1, ctx) for w in d.send_window_depths(ctx))


@given(bcast_scheds, contexts, st.data())
@settings(max_examples=200, deadline=None)
def test_broadcast_degrade_splices_and_keeps_contract(s, ctx, data):
    """degrade(live) splices dead ranks out of the shift permutation:
    same slab and tiling, offsets over the compacted live order only."""
    live = draw_live(data, s.n)
    d = s.degrade(live)
    if len(live) == s.n:
        assert d is s
        return
    assert type(d) is type(s) and d.n == len(live)
    assert (d.M_l, d.tile_m, d.fused) == (s.M_l, s.tile_m, s.fused)
    rounds = d.rounds
    offs = {(off, t) for off in range(1, d.n)
            for t in (range(d.nt) if d.fused else (0,))}
    assert len(rounds) == len(set(rounds)) and set(rounds) == offs
    assert rounds == sorted(rounds, key=lambda r: (r[1], r[0]))  # tile-major
    assert d.wire_rows() == (d.n - 1) * d.M_l      # no dead-rank edges
    assert all(1 <= w <= max(1, ctx) for w in d.send_window_depths(ctx))


@given(ring_scheds, contexts, st.data())
@settings(max_examples=200, deadline=None)
def test_ring_degrade_splices_and_keeps_contract(s, ctx, data):
    """degrade(live) closes the ring over the live order: same shard and
    chunking, len(live)-1 rotation steps, per-step window drain intact."""
    live = draw_live(data, s.n)
    d = s.degrade(live)
    if len(live) == s.n:
        assert d is s
        return
    assert type(d) is type(s) and d.n == len(live)
    assert (d.rows, d.kv_chunk, d.fused) == (s.rows, s.kv_chunk, s.fused)
    assert d.steps == len(live) - 1
    rounds = d.rounds
    assert len(rounds) == len(set(rounds)) and rounds == sorted(rounds)
    assert all(1 <= w <= max(1, ctx) for w in d.send_window_depths(ctx))


@given(st.lists(st.integers(0, 300), min_size=1, max_size=8), st.data())
@settings(max_examples=200, deadline=None)
def test_respill_conserves_tokens(counts, data):
    live = draw_live(data, len(counts))
    new = respill_counts(counts, live)
    assert len(new) == len(live)
    assert sum(new) == sum(counts)
    assert all(c >= counts[e] for c, e in zip(new, live))  # survivors keep own


# --------------------------------------------------------------- sanitizers

@given(st.integers(1, 256), st.integers(0, 512))
@settings(max_examples=200, deadline=None)
def test_sanitizers_return_divisors(B, req):
    ct = sanitize_combine_tile(req, B)
    assert B % ct == 0 and 1 <= ct <= B
    tm = sanitize_tile_m(req, B)
    assert B % tm == 0 and 1 <= tm <= B
    kc = sanitize_kv_chunk(req, B)
    assert B % kc == 0 and 1 <= kc <= B
    # one algorithm for the whole package (core/schedule.py::sanitize_tile)
    assert ct == tm == kc
