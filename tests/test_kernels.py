"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (single-device
kernels here; the multi-device remote-DMA kernels are swept in
test_multidevice.py via subprocess with simulated devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("BH,S,hd", [(1, 128, 64), (4, 256, 64),
                                     (2, 512, 128), (1, 128, 256),
                                     (3, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(BH, S, hd, causal, dtype):
    q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (BH, S, hd),
                                 dtype) for i in range(3))
    out = flash_attention(q, k, v, causal=causal)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("q_block,kv_block", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(q_block, kv_block):
    q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (2, 256, 64),
                                 jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, causal=True, q_block=q_block,
                          kv_block=kv_block)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_rejects_misaligned():
    q = jnp.zeros((1, 100, 64))
    with pytest.raises(AssertionError):
        flash_attention(q, q, q)


def test_flash_attention_numerics_extreme():
    """Large logits must not overflow the online softmax."""
    q = 30.0 * jax.random.normal(KEY, (1, 128, 64), jnp.float32)
    out = flash_attention(q, q, q, causal=True)
    assert np.all(np.isfinite(np.asarray(out)))
