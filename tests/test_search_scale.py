"""Search-correctness tier for the scaled search (docs/search.md).

The batched cascade and restructured generation loop are only allowed to
make the search *faster*, never *different*:

1. ``CandidateDB.is_novel``'s directive-key index makes exactly the same
   accept/reject decisions as the reference linear scan on a recorded
   proposal stream.
2. ``CascadeEvaluator.evaluate_batch`` matches sequential ``evaluate``
   bit-for-bit (deterministic fields) over a mixed generation — valid,
   l1-fail, l2-mismatch, quarantine-via-wedge, and fault-plan-scored
   candidates — and the l2 fan-out never exceeds the worker bound.
3. Two sequential ``slow_path`` runs of one ``SlowPathConfig`` produce
   identical ``db.history()`` and byte-identical telemetry payloads; a
   third batched run matches both.
"""
import json
import threading
import time

import jax
import pytest

from repro.core import (CONSERVATIVE, Candidate, CandidateDB,
                        CascadeEvaluator, SlowPathConfig,
                        extract_hardware_context, fast_path, random_directive,
                        slow_path)
from repro.core.faults import STRAGGLER, FaultPlan, FaultSpec
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload


@pytest.fixture(scope="module")
def rig():
    wl = get_workload("gemm_allgather", n_dev=1, M=512, K=512, N=512)
    mesh = make_mesh((1,), ("x",))
    hw = extract_hardware_context(mesh)
    return wl, mesh, hw


# ------------------------------------------------------- novelty index (a)


def _reference_is_novel(records, directive, code_text=""):
    """The pre-index implementation: per-proposal linear scan over every
    stored record (directive equality, plus the embedding branch whose
    reject condition also required ``as_dict`` equality)."""
    from repro.core.database import embed_code
    for r in records:
        if r.directive == directive:
            return False
    if code_text:
        q = embed_code(code_text)
        for r in records:
            e = embed_code(r.code_text or r.directive.render())
            if float(q @ e) > 0.995 \
                    and r.directive.as_dict() == directive.as_dict():
                return False
    return True


def test_novelty_index_matches_linear_scan(rig):
    """Replay a recorded proposal stream (mutated + resampled directives,
    heavy with duplicates) through the indexed ``is_novel`` and the
    reference scan: every accept/reject decision must be identical."""
    import random
    wl, _, hw = rig
    rng = random.Random(7)
    traits = wl.traits(hw)
    pool = [random_directive(rng, **traits) for _ in range(12)]
    stream = []
    for i in range(120):
        d = rng.choice(pool)
        if rng.random() < 0.5:      # tunable-refined variant of a pool point
            d = d.with_tunable("tile_m", rng.choice((32, 64, 128)))
        stream.append(d)
    db = CandidateDB()
    for i, d in enumerate(stream):
        want = _reference_is_novel(db.records, d, d.render())
        got = db.is_novel(d, d.render())
        assert got == want, (i, d)
        if got:                      # the search only stores accepted ones
            db.add(Candidate(directive=d))
    assert len(db.records) < len(stream)        # the stream really had dups


# ------------------------------------- batched vs sequential cascade (b/c)


class _Rigged:
    """Workload proxy that rigs specific failure modes by a sentinel
    tunable: ``rig=l1`` raises at build, ``rig=l2`` corrupts the output,
    ``rig=wedge`` sleeps far past the deadline at trace time."""

    def __init__(self, base):
        self._base = base

    def __getattr__(self, name):
        return getattr(self._base, name)

    def build(self, d, mesh):
        mode = d.tunable("rig")
        if mode == "l1":
            raise RuntimeError("rigged l1 build failure")
        if mode == "wedge":
            def wedged(*xs):
                time.sleep(60.0)
            return wedged
        fn = self._base.build(d, mesh)
        if mode == "l2":
            return lambda *xs: jax.tree.map(lambda a: a + 1.0, fn(*xs))
        return fn


class _BoundedEvaluator(CascadeEvaluator):
    """Counts concurrent ``_run_l2`` entries to assert the pool bound."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._lock = threading.Lock()
        self._inflight = 0
        self.max_inflight = 0

    def _run_l2(self, jfn):
        with self._lock:
            self._inflight += 1
            self.max_inflight = max(self.max_inflight, self._inflight)
        try:
            return super()._run_l2(jfn)
        finally:
            with self._lock:
                self._inflight -= 1


def _mixed_generation(seed_directive):
    base = seed_directive
    return [
        Candidate(directive=base, mutation="valid"),
        Candidate(directive=base.with_tunable("rig", "l1"), mutation="l1"),
        Candidate(directive=base.with_tunable("rig", "l2"), mutation="l2"),
        Candidate(directive=base.with_tunable("rig", "wedge"),
                  mutation="wedge"),
        Candidate(directive=base.with_tunable("tile_m", 64),
                  mutation="fault-scored"),
    ]


def test_batched_matches_sequential_mixed_generation(rig):
    wl, mesh, hw = rig
    rigged = _Rigged(wl)
    plan = FaultPlan("straggler", (FaultSpec(STRAGGLER, rank=0, rounds=4,
                                             delay_s=100e-6),))
    mk = lambda: _BoundedEvaluator(rigged, mesh, hw, timeout_s=1.5,
                                   fault_plans=(plan,), fault_weight=0.5)
    seed_d = CONSERVATIVE
    ev_seq, ev_bat = mk(), mk()
    seq = [ev_seq.evaluate(c) for c in _mixed_generation(seed_d)]
    bat = ev_bat.evaluate_batch(_mixed_generation(seed_d), max_workers=3)

    # every deterministic result field agrees pairwise
    for a, b in zip(seq, bat):
        assert (a.level, a.score, a.retries, a.quarantined) \
            == (b.level, b.score, b.retries, b.quarantined)
    assert [r.level for r in seq] == [3, 0, 1, 0, 3]
    assert seq[3].quarantined and bat[3].quarantined
    assert seq[4].record.to_dict()["fault_penalty_ms"] > 0.0

    # the published record / quarantine streams are identical in order
    # and content (wall-clock projection removed)
    assert [r.deterministic_dict() for r in ev_seq.records] \
        == [r.deterministic_dict() for r in ev_bat.records]
    assert [q["diagnostic"] for q in ev_seq.quarantine] \
        == [q["diagnostic"] for q in ev_bat.quarantine]

    # the l2 fan-out stayed inside the requested pool bound
    assert 1 <= ev_bat.max_inflight <= 3
    assert ev_seq.max_inflight == 1


def test_batch_worker_bound_respected(rig):
    wl, mesh, hw = rig
    ev = _BoundedEvaluator(wl, mesh, hw)
    cands = [Candidate(directive=CONSERVATIVE.with_tunable("tile_m", t))
             for t in (16, 32, 64, 128, 256, 16, 32, 64)]
    res = ev.evaluate_batch(cands, max_workers=2)
    assert all(r.ok for r in res)
    assert ev.max_inflight <= 2
    assert len(ev.records) == len(cands)


# --------------------------------------- deterministic slow_path (b)


def test_slow_path_deterministic_and_batched_parity(rig):
    wl, mesh, hw = rig
    seed = fast_path(wl, mesh, hw)
    cfg = SlowPathConfig(islands=2, generations=3, seed=3)
    r1 = slow_path(seed, mesh, hw, cfg)
    r2 = slow_path(seed, mesh, hw, cfg)
    r3 = slow_path(seed, mesh, hw, cfg, batched=True, eval_workers=3)
    assert r1.history == r2.history == r3.history
    p1 = json.dumps(r1.telemetry.payload(), sort_keys=True)
    p2 = json.dumps(r2.telemetry.payload(), sort_keys=True)
    p3 = json.dumps(r3.telemetry.payload(), sort_keys=True)
    assert p1 == p2 == p3
    assert r1.best.score >= r1.seed_score
    # the parity invariant covers the per-record projection too
    assert [r.deterministic_dict()
            for r in r1.telemetry.records] \
        == [r.deterministic_dict() for r in r3.telemetry.records]
