"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU, asserting shapes and finiteness.
Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced
from repro.models import (StepOptions, decode_step, init_params,
                          prefill_step, train_loss)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, with_labels=True):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        b["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model),
                                        jnp.bfloat16)
    if cfg.num_patch_tokens:
        b["patches"] = jax.random.normal(
            KEY, (B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train(arch):
    cfg = reduced(get_arch(arch))
    params = init_params(KEY, cfg)
    loss = jax.jit(lambda p, b: train_loss(p, b, cfg, None))(
        params, _batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_decode(arch):
    cfg = reduced(get_arch(arch))
    params = init_params(KEY, cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S, with_labels=False)
    logits, cache = prefill_step(params, batch, cfg, None, seq_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = decode_step(params, cache, tok, jnp.int32(S), cfg, None)
    assert logits2.shape == (B, 1, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_gradients_flow(arch):
    cfg = reduced(get_arch(arch))
    params = init_params(KEY, cfg)
    grads = jax.grad(lambda p: train_loss(p, _batch(cfg), cfg, None))(params)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(n) for n in norms), arch
    assert sum(norms) > 0, arch


def test_decode_matches_prefill_continuation():
    """Prefill(S) then decode(t) must equal prefill(S+1) logits (llama)."""
    cfg = reduced(get_arch("llama3.2-1b"), dtype="float32")
    params = init_params(KEY, cfg)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    lo_full, _ = prefill_step(params, {"tokens": toks}, cfg, None,
                              seq_len=S + 1)
    lo_pre, cache = prefill_step(params, {"tokens": toks[:, :S]}, cfg, None,
                                 seq_len=S + 1)
    lo_dec, _ = decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S),
                            cfg, None)
    np.testing.assert_allclose(np.asarray(lo_dec), np.asarray(lo_full),
                               rtol=1e-4, atol=1e-4)


def test_recurrent_decode_matches_prefill():
    """Same continuation property for the recurrent families."""
    for arch in ("xlstm-350m", "recurrentgemma-9b"):
        cfg = reduced(get_arch(arch), dtype="float32")
        params = init_params(KEY, cfg)
        B, S = 2, 16
        toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
        lo_full, _ = prefill_step(params, {"tokens": toks}, cfg, None,
                                  seq_len=S + 1)
        lo_pre, cache = prefill_step(params, {"tokens": toks[:, :S]}, cfg,
                                     None, seq_len=S + 1)
        lo_dec, _ = decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S),
                                cfg, None)
        np.testing.assert_allclose(np.asarray(lo_dec), np.asarray(lo_full),
                                   rtol=2e-3, atol=2e-3, err_msg=arch)


def test_llava_patch_positions_masked():
    cfg = reduced(get_arch("llava-next-mistral-7b"))
    params = init_params(KEY, cfg)
    b = _batch(cfg)
    b["labels"] = b["labels"].at[:, :cfg.num_patch_tokens].set(-1)
    loss = train_loss(params, b, cfg, None)
    assert np.isfinite(float(loss))


def test_param_count_close_to_analytic():
    for arch in ("llama3.2-1b", "phi3-mini-3.8b", "granite-20b"):
        cfg = get_arch(arch)
        sds = jax.eval_shape(lambda k: init_params(k, cfg), KEY)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
        # padded vocab adds a bit; analytic should be within 5%
        assert abs(actual - cfg.param_count()) / cfg.param_count() < 0.05, arch


def test_chunked_loss_equals_full():
    cfg = reduced(get_arch("llama3.2-1b"), dtype="float32")
    params = init_params(KEY, cfg)
    b = _batch(cfg, B=2, S=64)
    l_full = train_loss(params, b, cfg, None, StepOptions(loss_chunk=0))
    l_chunk = train_loss(params, b, cfg, None, StepOptions(loss_chunk=16))
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=1e-5)


def test_scan_vs_unrolled_layers():
    cfg = reduced(get_arch("llama3.2-1b"), num_layers=4, dtype="float32")
    params = init_params(KEY, cfg)
    b = _batch(cfg)
    l_scan = train_loss(params, b, cfg, None, StepOptions(scan_layers=True))
    l_unroll = train_loss(params, b, cfg, None,
                          StepOptions(scan_layers=False))
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)
