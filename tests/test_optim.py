"""AdamW vs a numpy reference; schedule; clipping; ZeRO spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import Rules, zero_spec
from repro.optim import AdamWConfig, adamw_update, init_opt_state, lr_at


def _np_adamw(w, g, m, v, step, cfg, lr):
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m2 / (1 - cfg.b1 ** step)
    vh = v2 / (1 - cfg.b2 ** step)
    w2 = w - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
    return w2, m2, v2


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, clip_norm=1e9,
                      weight_decay=0.1)
    w = np.random.default_rng(0).standard_normal((4, 8)).astype(np.float32)
    g = np.random.default_rng(1).standard_normal((4, 8)).astype(np.float32)
    g = g / np.linalg.norm(g) * 0.1          # below clip
    params = {"w": jnp.asarray(w)}
    state = init_opt_state(params)
    new_p, new_s, gnorm = adamw_update(params, {"w": jnp.asarray(g)}, state,
                                       cfg)
    lr = float(lr_at(cfg, 1))
    w_ref, m_ref, v_ref = _np_adamw(w, g, np.zeros_like(w), np.zeros_like(w),
                                    1, cfg, lr)
    np.testing.assert_allclose(np.asarray(new_p["w"]), w_ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_s["m"]["w"]), m_ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_s["v"]["w"]), v_ref, rtol=1e-5)


def test_global_norm_clip():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0,
                      peak_lr=1.0, eps=1e-8)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(params, g, init_opt_state(params), cfg)
    assert float(gnorm) == pytest.approx(200.0)


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == pytest.approx(0.0)
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0, rel=1e-2)
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr_at(cfg, 55)) < 1.0


def test_bf16_params_keep_f32_master():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = init_opt_state(params)
    assert state["master"]["w"].dtype == jnp.float32
    cfg = AdamWConfig(warmup_steps=0)
    new_p, new_s, _ = adamw_update(params, {"w": jnp.ones((8,), jnp.bfloat16)},
                                   state, cfg)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_s["master"]["w"].dtype == jnp.float32


def test_zero_spec_adds_data_axis():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
    rules = Rules.__new__(Rules)
    rules.mesh = FakeMesh()
    rules.table = {"zero": ("data",)}
    rules._dp, rules._tp = ("data",), ("model",)
    sp = zero_spec(P(None, "model"), (64, 32), rules)
    assert sp == P("data", "model")
    # already data-sharded: unchanged
    sp2 = zero_spec(P("data", None), (64, 32), rules)
    assert sp2 == P("data", None)
    # nothing divides: unchanged
    sp3 = zero_spec(P(None, "model"), (3, 32), rules)
    assert sp3 == P(None, "model")
