"""Expert-system (paper Table 3) reachability + tight-wire cost accounting.

These run without hypothesis and without simulated devices (the FLUX
cascade test uses the default 1-device jax): directive validity and the l3
analytic model are pure functions. The executable 4-rank interpret-mode
counterparts live in tests/scripts/moe_dispatch_suite.py.
"""
import pytest

from repro.core.design_space import (CONSERVATIVE, EXPERT_SYSTEMS, Directive,
                                     is_valid, violations)
from repro.core.hardware import V5E, HardwareContext
from repro.workloads import get_workload

HW = HardwareContext(chip=V5E, mesh_shape=(4,), mesh_axes=("x",),
                     chips_per_pod=4, n_chips=4, has_dcn=False)


def moe(**kw):
    kw.setdefault("n_dev", 4)
    kw.setdefault("tokens_per_rank", 4096)
    kw.setdefault("d", 7168)
    kw.setdefault("f", 2048)
    return get_workload("moe_dispatch", **kw)


def test_conservative_always_valid():
    for dcn in (False, True):
        for ring in (False, True):
            assert is_valid(CONSERVATIVE, has_dcn=dcn, kernelizable=False,
                            ring_topology=ring)


def test_expert_systems_are_points_in_C():
    for name, d in EXPERT_SYSTEMS.items():
        v = violations(d, has_dcn=False, kernelizable=True,
                       ring_topology=False)
        assert not v, (name, v)


def test_moe_dispatch_is_kernelizable():
    """The flagship workload now reaches the PALLAS_RDMA region of C."""
    w = moe()
    assert w.kernelizable
    assert w.traits(HW)["kernelizable"]


def test_every_table3_directive_valid_for_moe_dispatch():
    """ISSUE-1: DeepEP NVL/IB, FLUX and TokenWeave all pass violations()
    under the moe_dispatch workload traits."""
    w = moe()
    for name, d in EXPERT_SYSTEMS.items():
        v = w.check(d, HW)
        assert not v, (name, v)


# --------------------------------------------------------- wire accounting

TIGHT = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
                  "GRID_STEP", "PER_PEER", "ACQUIRE", 2,
                  tunables=(("tight", 1),))
PADDED_KERNEL = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
                          "GRID_STEP", "PER_CHUNK", "ACQUIRE", 2)
HOST = Directive("XLA_COLLECTIVE", placement="DEFERRED",
                 granularity="PER_CHUNK")
DEEPEP_NVL = EXPERT_SYSTEMS["DeepEP (NVL)"]


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_tight_wire_charges_exact_offrank_tokens(skew):
    """granularity=PER_PEER + tight=1 charges exactly counts.sum() -
    counts[0] dispatched tokens (and the schedule agrees). The l3 model
    also charges the dummy-elided round count — real hardware skips the
    interpreter's lockstep padding — so the cost delta is the wire-byte
    difference plus the per-round sync difference of the tighter schedule.
    """
    from repro.kernels.moe_dispatch import make_schedule
    from repro.workloads.base import TILE_SYNC

    w = moe(skew=skew)
    counts = w._counts(w.T)
    sched = make_schedule(counts, tight=True)
    assert sched.wire_tokens(0) == int(counts.sum() - counts[0])
    padded = make_schedule(counts, tight=False)
    assert padded.wire_tokens(0) == int(counts.max()) * (w.n_dev - 1)
    # the tight schedule issues strictly fewer real rounds than the padded
    # one, and elision only ever removes rounds
    assert sched.issued_rounds(elide_dummy=True) \
        < padded.issued_rounds(elide_dummy=True)
    assert sched.issued_rounds(elide_dummy=True) \
        <= sched.issued_rounds(elide_dummy=False)
    # the exact-token credit shows up as a cost delta of precisely the
    # dispatch+combine byte difference between tight and padded wire (on
    # the additive DEFERRED path, where no overlap hides dispatch time),
    # plus the dispatch+combine round-sync delta of the elided schedule
    tight_seq = Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                          "KERNEL", "PER_PEER", "ACQUIRE", 1,
                          tunables=(("tight", 1),))
    padded_seq = Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                           "KERNEL", "PER_CHUNK", "ACQUIRE", 1)
    tight_cost = w.analytic_cost(tight_seq, HW)
    padded_cost = w.analytic_cost(padded_seq, HW)
    dtok = padded.wire_tokens(0) - sched.wire_tokens(0)
    dt = dtok * w.d * (2 + 2) / HW.chip.ici_link_bw   # dispatch bf16 + comb
    dt += (padded.issued_rounds(elide_dummy=True)
           - sched.issued_rounds(elide_dummy=True)) * TILE_SYNC
    # combine rounds are rank-dependent; for the busiest rank (the one the
    # model bounds on) every combine round is real, so no elision delta
    dt += (padded.combine_issued_rounds(0, elide_dummy=True)
           - sched.combine_issued_rounds(0, elide_dummy=True)) * TILE_SYNC
    assert padded_cost - tight_cost == pytest.approx(dt, rel=1e-6)


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_tight_strictly_cheaper_than_padded(skew):
    w = moe(skew=skew)
    assert w.analytic_cost(TIGHT, HW) < w.analytic_cost(PADDED_KERNEL, HW)


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_deepep_points_beat_padded_host_baseline(skew):
    """fig4 acceptance: the PALLAS_RDMA tight-dispatch rows beat the padded
    host baseline at every skew >= 2, and the pipelined refinement beats
    the conservative DeepEP-NVL point."""
    w = moe(skew=skew)
    host = w.analytic_cost(HOST, HW)
    nvl = w.analytic_cost(DEEPEP_NVL, HW)
    tight = w.analytic_cost(TIGHT, HW)
    assert nvl < host
    assert tight < host
    assert tight <= nvl


def test_fig4_reports_deepep_rows():
    from benchmarks import fig4_moe_skew

    rows = fig4_moe_skew.run()
    names = [r[0] for r in rows]
    for skew in (2, 3, 4, 5):
        assert f"fig4/moe_skew{skew}_deepep_tight" in names
        host = next(r for r in rows if r[0] == f"fig4/moe_skew{skew}_host")
        tight = next(r for r in rows
                     if r[0] == f"fig4/moe_skew{skew}_deepep_tight")
        assert tight[1] < host[1], skew
        flux = next(r for r in rows if r[0] == f"fig4/moe_skew{skew}_flux")
        assert flux[1] < host[1], skew


def test_fig4_n_dev_parameter():
    """The --n-dev flag reshapes the whole sweep (default 2, paper shape)."""
    from benchmarks import fig4_moe_skew

    rows8 = fig4_moe_skew.run(n_dev=8)
    host8 = next(r for r in rows8 if r[0] == "fig4/moe_skew3_host")
    flux8 = next(r for r in rows8 if r[0] == "fig4/moe_skew3_flux")
    assert flux8[1] < host8[1]


# --------------------------------------------------------- the FLUX point

FLUX = EXPERT_SYSTEMS["FLUX"]


def test_flux_is_tile_fused_counter_and_valid():
    """FLUX = TILE_FUSED placement + COUNTER completion (CoCoNet-style
    fusion of the GEMM tile loop with per-tile combine writes), and it
    validates for the kernelizable moe_dispatch traits."""
    assert FLUX.placement == "TILE_FUSED"
    assert FLUX.completion == "COUNTER"
    assert not moe().check(FLUX, HW)


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_flux_models_per_tile_combine_overlap(skew):
    """The fused point beats host at every skew, and its combine exposure
    shrinks with the tick count: only the last tile's write is exposed."""
    w = moe(skew=skew)
    assert w.analytic_cost(FLUX, HW) < w.analytic_cost(HOST, HW)
    # finer combine tiles trade smaller exposed combine against more
    # counter ticks — the knob has a real optimum, not a monotone best
    coarse = w.analytic_cost(FLUX.with_tunable("combine_tile", 64), HW)
    fine = w.analytic_cost(FLUX.with_tunable("combine_tile", 8), HW)
    assert coarse != fine
    # a deeper send window shrinks the per-tile recycle stall, so the
    # contexts knob is visible to the search on the fused point too
    import dataclasses
    deeper = dataclasses.replace(FLUX, contexts=2)
    assert w.analytic_cost(deeper, HW) < w.analytic_cost(FLUX, HW)


def test_flux_cascade_reaches_l3():
    """The FLUX directive builds, verifies under interpret mode, and scores
    at l3 through the full cascade (1-rank mesh; the 4-rank version runs in
    tests/scripts/moe_dispatch_suite.py)."""
    from repro.core.cascade import Candidate, CascadeEvaluator
    from repro.core.hardware import extract_hardware_context
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("x",))
    w = get_workload("moe_dispatch", n_dev=1, tokens_per_rank=128, d=32,
                     f=64)
    hw = extract_hardware_context(mesh)
    res = CascadeEvaluator(w, mesh, hw).evaluate(Candidate(directive=FLUX))
    assert res.level == 3, res.diagnostic
    assert res.score > 0


# ------------------------------------------------ slow-path tunable space

def test_kernel_knobs_are_in_slow_path_search_space():
    """block_tokens / combine_tile / contexts are refinable dimensions of
    the diff-patch mutation space for the kernelized points."""
    import random

    from repro.core.cascade import Candidate, EvalResult
    from repro.core.design_space import TUNABLES
    from repro.core.mutation import HeuristicMutator, MutationContext
    from repro.core.slow_path import _tunable_space

    space = _tunable_space(moe())
    for name in ("block_tokens", "combine_tile", "contexts", "tight",
                 "wire_i8"):
        assert name in space, name
        assert space[name] == TUNABLES[name]

    # a diff-patch mutation can actually move each knob on a FLUX parent
    traits = moe().traits(HW)
    parent = Candidate(directive=FLUX)
    parent.result = EvalResult(3, 100.0, 1.0, diagnostic="ok: modeled")
    ctx = MutationContext(parent=parent, phase="exploit", traits=traits,
                          tunable_space=space)
    mut = HeuristicMutator()
    moved = set()
    for seed in range(400):
        rng = random.Random(seed)
        child, form = mut.propose(ctx, rng)
        if child.contexts != parent.directive.contexts:
            moved.add("contexts")
        for name in ("block_tokens", "combine_tile"):
            if child.tunable(name) != parent.directive.tunable(name):
                moved.add(name)
    assert {"block_tokens", "combine_tile", "contexts"} <= moved, moved
