"""Expert-system (paper Table 3) reachability + tight-wire cost accounting.

These run without hypothesis and without simulated devices: directive
validity and the l3 analytic model are pure functions. The executable
(interpret-mode) counterparts live in tests/scripts/moe_dispatch_suite.py.
"""
import pytest

from repro.core.design_space import (CONSERVATIVE, EXPERT_SYSTEMS, Directive,
                                     is_valid, violations)
from repro.core.hardware import V5E, HardwareContext
from repro.workloads import get_workload

HW = HardwareContext(chip=V5E, mesh_shape=(4,), mesh_axes=("x",),
                     chips_per_pod=4, n_chips=4, has_dcn=False)


def moe(**kw):
    kw.setdefault("n_dev", 4)
    kw.setdefault("tokens_per_rank", 4096)
    kw.setdefault("d", 7168)
    kw.setdefault("f", 2048)
    return get_workload("moe_dispatch", **kw)


def test_conservative_always_valid():
    for dcn in (False, True):
        for ring in (False, True):
            assert is_valid(CONSERVATIVE, has_dcn=dcn, kernelizable=False,
                            ring_topology=ring)


def test_expert_systems_are_points_in_C():
    for name, d in EXPERT_SYSTEMS.items():
        v = violations(d, has_dcn=False, kernelizable=True,
                       ring_topology=False)
        assert not v, (name, v)


def test_moe_dispatch_is_kernelizable():
    """The flagship workload now reaches the PALLAS_RDMA region of C."""
    w = moe()
    assert w.kernelizable
    assert w.traits(HW)["kernelizable"]


def test_every_table3_directive_valid_for_moe_dispatch():
    """ISSUE-1: DeepEP NVL/IB, FLUX and TokenWeave all pass violations()
    under the moe_dispatch workload traits."""
    w = moe()
    for name, d in EXPERT_SYSTEMS.items():
        v = w.check(d, HW)
        assert not v, (name, v)


# --------------------------------------------------------- wire accounting

TIGHT = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
                  "GRID_STEP", "PER_PEER", "ACQUIRE", 2,
                  tunables=(("tight", 1),))
PADDED_KERNEL = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", "LOCAL",
                          "GRID_STEP", "PER_CHUNK", "ACQUIRE", 2)
HOST = Directive("XLA_COLLECTIVE", placement="DEFERRED",
                 granularity="PER_CHUNK")
DEEPEP_NVL = EXPERT_SYSTEMS["DeepEP (NVL)"]


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_tight_wire_charges_exact_offrank_tokens(skew):
    """granularity=PER_PEER + tight=1 charges exactly counts.sum() -
    counts[0] dispatched tokens (and the schedule agrees)."""
    from repro.kernels.moe_dispatch import make_schedule

    w = moe(skew=skew)
    counts = w._counts(w.T)
    sched = make_schedule(counts, tight=True)
    assert sched.wire_tokens(0) == int(counts.sum() - counts[0])
    padded = make_schedule(counts, tight=False)
    assert padded.wire_tokens(0) == int(counts.max()) * (w.n_dev - 1)
    # the exact-token credit shows up as a cost delta of precisely the
    # dispatch+combine byte difference between tight and padded wire (on
    # the additive DEFERRED path, where no overlap hides dispatch time)
    tight_seq = Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                          "KERNEL", "PER_PEER", "ACQUIRE", 1,
                          tunables=(("tight", 1),))
    padded_seq = Directive("PALLAS_RDMA", "SIGNAL", "DEFERRED", "LOCAL",
                           "KERNEL", "PER_CHUNK", "ACQUIRE", 1)
    tight_cost = w.analytic_cost(tight_seq, HW)
    padded_cost = w.analytic_cost(padded_seq, HW)
    dtok = padded.wire_tokens(0) - sched.wire_tokens(0)
    dt = dtok * w.d * (2 + 2) / HW.chip.ici_link_bw   # dispatch bf16 + comb
    assert padded_cost - tight_cost == pytest.approx(dt, rel=1e-6)


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_tight_strictly_cheaper_than_padded(skew):
    w = moe(skew=skew)
    assert w.analytic_cost(TIGHT, HW) < w.analytic_cost(PADDED_KERNEL, HW)


@pytest.mark.parametrize("skew", [2.0, 3.0, 4.0, 5.0])
def test_deepep_points_beat_padded_host_baseline(skew):
    """fig4 acceptance: the PALLAS_RDMA tight-dispatch rows beat the padded
    host baseline at every skew >= 2, and the pipelined refinement beats
    the conservative DeepEP-NVL point."""
    w = moe(skew=skew)
    host = w.analytic_cost(HOST, HW)
    nvl = w.analytic_cost(DEEPEP_NVL, HW)
    tight = w.analytic_cost(TIGHT, HW)
    assert nvl < host
    assert tight < host
    assert tight <= nvl


def test_fig4_reports_deepep_rows():
    from benchmarks import fig4_moe_skew

    rows = fig4_moe_skew.run()
    names = [r[0] for r in rows]
    for skew in (2, 3, 4, 5):
        assert f"fig4/moe_skew{skew}_deepep_tight" in names
        host = next(r for r in rows if r[0] == f"fig4/moe_skew{skew}_host")
        tight = next(r for r in rows
                     if r[0] == f"fig4/moe_skew{skew}_deepep_tight")
        assert tight[1] < host[1], skew
