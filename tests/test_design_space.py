"""Property tests for the structured design space C (paper §3.1)."""
import random

import pytest

# property tests need hypothesis (optional test dep): skip, not error.
# The non-hypothesis design-space tests live in test_expert_points.py so
# they still run when hypothesis is absent.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.design_space import (BACKENDS, COMPLETIONS, CONSERVATIVE,
                                     CONTEXTS, DIMENSIONS, EXPERT_SYSTEMS,
                                     GRANULARITIES, ISSUERS, ORDERINGS,
                                     PLACEMENTS, SCOPES, Directive,
                                     enumerate_valid, is_valid,
                                     random_directive, violations)
from repro.core.mutation import HeuristicMutator, MutationContext, \
    parse_directive
from repro.core.cascade import Candidate, EvalResult

directives = st.builds(
    Directive,
    backend=st.sampled_from(BACKENDS),
    completion=st.sampled_from(COMPLETIONS),
    placement=st.sampled_from(PLACEMENTS),
    scope=st.sampled_from(SCOPES),
    issuer=st.sampled_from(ISSUERS),
    granularity=st.sampled_from(GRANULARITIES),
    ordering=st.sampled_from(ORDERINGS),
    contexts=st.sampled_from(CONTEXTS),
)
traits = st.fixed_dictionaries({
    "has_dcn": st.booleans(),
    "kernelizable": st.booleans(),
    "ring_topology": st.booleans(),
})


def test_conservative_is_always_valid():
    for dcn in (False, True):
        for ring in (False, True):
            assert is_valid(CONSERVATIVE, has_dcn=dcn, kernelizable=False,
                            ring_topology=ring)


def test_expert_systems_are_points_in_C():
    # paper Table 3: DeepEP / FLUX / TokenWeave map onto C. The TPU-adapted
    # coordinates live in a single ICI domain (the fabric that plays the
    # role of NVLink/IB); cross-DCN deployments require HYBRID (DESIGN.md).
    for name, d in EXPERT_SYSTEMS.items():
        v = violations(d, has_dcn=False, kernelizable=True,
                       ring_topology=False)
        assert not v, (name, v)


@given(directives, traits)
@settings(max_examples=200, deadline=None)
def test_violations_consistent_with_is_valid(d, tr):
    assert is_valid(d, **tr) == (not violations(d, **tr))


@given(st.integers(0, 10_000), traits)
@settings(max_examples=50, deadline=None)
def test_random_directive_is_valid(seed, tr):
    rng = random.Random(seed)
    d = random_directive(rng, **tr)
    assert is_valid(d, **tr)


@given(st.integers(0, 10_000), traits, st.sampled_from(["explore", "exploit"]))
@settings(max_examples=100, deadline=None)
def test_mutator_is_bounded_operator(seed, tr, phase):
    """The paper's core claim: the mutation operator only emits valid points
    of C (bounded by the domain, not free-form)."""
    rng = random.Random(seed)
    parent = Candidate(directive=random_directive(rng, **tr))
    parent.result = EvalResult(3, 100.0, 1.0)
    ctx = MutationContext(parent=parent, phase=phase, traits=tr,
                          tunable_space={"tile_m": (64, 128, 256)})
    d, form = HeuristicMutator().propose(ctx, rng)
    assert is_valid(d, **tr), (d, form)


@given(directives)
@settings(max_examples=100, deadline=None)
def test_render_parse_roundtrip(d):
    d2 = parse_directive(d.render(), fallback=CONSERVATIVE)
    assert d2.as_dict() == {**d.as_dict(), "tunables": {}}


def test_enumerate_valid_nonempty_and_bounded():
    all_valid = list(enumerate_valid(has_dcn=False, kernelizable=True,
                                     ring_topology=True))
    assert len(all_valid) > 50
    total = 1
    for vals in DIMENSIONS.values():
        total *= len(vals)
    assert len(all_valid) < total          # constraints prune the space


def test_directive_tunables_immutable_update():
    d = CONSERVATIVE.with_tunable("tile_m", 64)
    assert d.tunable("tile_m") == 64
    assert CONSERVATIVE.tunable("tile_m") is None
    assert d.with_tunable("tile_m", 128).tunable("tile_m") == 128
