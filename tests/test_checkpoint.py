"""Checkpointing: round-trip identity (hypothesis), atomicity, retention,
bf16 handling, manifest recovery."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")       # optional test dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)


def test_roundtrip_identity(tmp_path):
    state = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
             "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                        "step": jnp.int32(7)}}
    save_checkpoint(tmp_path, 5, state)
    restored, step = restore_checkpoint(tmp_path, state)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@given(st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=32),
       st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_roundtrip_property(vals, step):
    import tempfile
    state = {"w": jnp.asarray(vals, jnp.float32)}
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, step, state)
        restored, s = restore_checkpoint(td, state)
        assert s == step
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))


def test_no_tmp_files_left(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.ones(3)})
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_retention(tmp_path):
    for s in range(6):
        save_checkpoint(tmp_path, s, {"w": jnp.ones(3)}, keep=3)
    ckpts = sorted(pathlib.Path(tmp_path).glob("step_*.npz"))
    assert len(ckpts) == 3
    assert latest_step(tmp_path) == 5


def test_restore_missing_returns_none(tmp_path):
    state, step = restore_checkpoint(tmp_path, {"w": jnp.ones(3)})
    assert state is None and step is None


def test_elastic_restore_shape_checked(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.ones((4, 4))})
    with pytest.raises(AssertionError):
        restore_checkpoint(tmp_path, {"w": jnp.ones((2, 4))})
