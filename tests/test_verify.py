"""l0 schedule sanitizer (core/verify.py) + its cascade wiring.

Tier-1 coverage of the static-verification contract that needs no
devices:

  * every sanitized schedule the four builders emit — healthy and
    degraded, across the ``TUNABLES['contexts']`` grid and the lowering
    knob grid — passes l0 with zero diagnostics (no false positives);
  * every seeded mutation class in :data:`MUTATION_CLASSES` is rejected
    with its class-specific checker code as the *first* diagnostic;
  * ``CascadeEvaluator`` runs l0 ahead of l1/l2: a failing report stops
    the candidate at level 0 with a ``"l0:<code>"`` rejection class and
    l2 is never entered; clean candidates carry an ``"l0"`` timing;
  * ``EvalRecord.rejection``/``.stage`` round-trip JSON, ``stage`` stays
    out of the batch-parity projection, and quarantine entries name the
    level that was in flight;
  * an optional Hypothesis property fuzz over schedule parameters
    (skipped when hypothesis is not installed — the grid sweep above is
    the deterministic floor).
"""
import time

import jax.numpy as jnp
import pytest

from repro.core import extract_hardware_context
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import (CONSERVATIVE, EXPERT_SYSTEMS, TUNABLES,
                                     Directive)
from repro.core.schedule import (make_broadcast_schedule, make_ring_schedule,
                                 make_schedule)
from repro.core.telemetry import EvalRecord
from repro.core.verify import (CHECKS, EXPECTED_CODE, MUTATION_CLASSES,
                               VerifyReport, apply_mutation, lower_dispatch,
                               lower_ring, mutation_corpus, verify_directive,
                               verify_program, verify_schedule)
from repro.launch.mesh import make_mesh
from repro.workloads import WORKLOADS, get_workload
from repro.workloads.base import Workload


@pytest.fixture(scope="module")
def hw():
    return extract_hardware_context(make_mesh((1,), ("x",)))


# ------------------------------------------------- clean schedules pass l0

DISPATCH_COUNTS = ((96, 64, 33, 17), (64, 64, 64, 64), (40, 0, 23, 65))


@pytest.mark.parametrize("counts", DISPATCH_COUNTS)
@pytest.mark.parametrize("tight", [True, False])
def test_dispatch_schedules_pass_l0(counts, tight):
    sched = make_schedule(counts, 32, tight)
    rep = verify_schedule(sched)
    assert rep.ok, rep.summary()
    assert rep.checked.get("programs") == len(TUNABLES["contexts"])


@pytest.mark.parametrize("knobs", [
    dict(tile_fused=True, combine_tile=16),
    dict(tile_fused=True, combine_tile=32, wire_i8=1),
    dict(barrier=True, pipelined=False),
    dict(pipelined=True, wire_i8=1),
    dict(pipelined=False),
])
def test_dispatch_knob_grid_passes_l0(knobs):
    sched = make_schedule((96, 64, 33, 17), 32, True)
    for cx in TUNABLES["contexts"]:
        rep = verify_program(lower_dispatch(sched, cx, **knobs))
        assert rep.ok, f"{knobs} cx={cx}: {rep.summary()}"


@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("counter", [True, False])
def test_broadcast_schedules_pass_l0(fused, counter):
    sched = make_broadcast_schedule(4, 256, 64, fused)
    rep = verify_schedule(sched, knobs={"counter": counter})
    assert rep.ok, rep.summary()


@pytest.mark.parametrize("n", [2, 3, 4])
@pytest.mark.parametrize("fused", [True, False])
def test_ring_schedules_pass_l0(n, fused):
    sched = make_ring_schedule(n, 128, 32, fused)
    for knobs in (dict(counter=True), dict(counter=False),
                  dict(counter=True, pipelined=False),
                  dict(counter=False, eager=True)):
        rep = verify_schedule(sched, knobs=knobs)
        assert rep.ok, f"n={n} fused={fused} {knobs}: {rep.summary()}"


def test_degraded_schedules_pass_l0_with_parent_contract():
    disp = make_schedule((96, 64, 33, 17), 32, True)
    live = (0, 1, 3)
    rep = verify_schedule(disp.degrade(live), parent=disp, live=live)
    assert rep.ok, rep.summary()
    ring = make_ring_schedule(4, 128, 32, True)
    rep = verify_schedule(ring.degrade((0, 2, 3)), parent=ring,
                          live=(0, 2, 3))
    assert rep.ok, rep.summary()


def test_verify_directive_over_expert_system_points(hw):
    """Every deployable (workload, expert-system) point is l0-clean;
    XLA-backed points are vacuous (no collective schedule -> None)."""
    points = dict(EXPERT_SYSTEMS)
    points["CONSERVATIVE"] = CONSERVATIVE
    vacuous = kernelized = 0
    for wname in sorted(WORKLOADS):
        wl = get_workload(wname)
        for pname, d in sorted(points.items()):
            if wl.check(d, hw):
                continue
            rep = verify_directive(wl, d)
            if rep is None:
                assert d.backend == "XLA_COLLECTIVE" or wl.n_dev < 2
                vacuous += 1
            else:
                assert rep.ok, f"{wname}/{pname}: {rep.summary()}"
                kernelized += 1
    assert kernelized >= 10 and vacuous >= 5


# -------------------------------------------------- seeded-mutation corpus


def test_mutation_corpus_covers_every_class():
    corpus = mutation_corpus()
    assert tuple(e["cls"] for e in corpus) == MUTATION_CLASSES
    assert len(MUTATION_CLASSES) >= 8


@pytest.mark.parametrize("entry", mutation_corpus(),
                         ids=lambda e: e["cls"])
def test_mutation_class_caught_with_specific_code(entry):
    rep = entry["run"]()
    assert not rep.ok, f"{entry['cls']} not caught"
    first = rep.errors[0]
    assert first.code == entry["expect"] == EXPECTED_CODE[entry["cls"]]
    assert first.code in CHECKS
    assert first.detail                      # a precise, non-empty message
    assert first.code in rep.summary(limit=1)


def test_apply_mutation_rejects_schedule_level_and_unknown_classes():
    prog = lower_ring(make_ring_schedule(4, 64, 32, True), 2)
    with pytest.raises(ValueError, match="schedule-level"):
        apply_mutation(prog, "non_conserving_respill")
    with pytest.raises(ValueError, match="unknown mutation class"):
        apply_mutation(prog, "flipped_parity")
    # a mutation never aliases its input program
    mut = apply_mutation(prog, "dropped_signal")
    assert verify_program(prog).ok and not verify_program(mut).ok


# ------------------------------------------------------- cascade l0 wiring


class ToyWorkload(Workload):
    """Minimal 1-rank workload: no collective schedule, so the default
    l0 pass is vacuous — the sabotage subclass below injects reports."""
    name = "toy_verify"

    def __init__(self, n_dev=2, sleep_s=0.0):
        self.n_dev = n_dev
        self.sleep_s = sleep_s

    def check(self, d, hw=None):
        return []

    def example_inputs(self, key, mesh):
        return (jnp.ones((4, 4), jnp.float32),)

    def reference(self, x):
        return x * 2.0

    def build(self, d, mesh):
        if self.sleep_s:
            def wedged(x):
                time.sleep(self.sleep_s)
                return x * 2.0
            return wedged
        return lambda x: x * 2.0

    def analytic_cost(self, d, hw):
        return 1e-3 / self.n_dev

    def degrade(self, live_ranks):
        return self

    def state_bytes_per_rank(self):
        return 10 * 2**20


def test_cascade_clean_candidate_times_l0(hw):
    mesh = make_mesh((1,), ("x",))
    ev = CascadeEvaluator(ToyWorkload(), mesh, hw)
    res = ev.evaluate(Candidate(directive=CONSERVATIVE))
    assert res.ok and res.rejection == ""
    rec = res.record
    assert "l0" in rec.levels_s and rec.levels_s["l0"] >= 0.0
    assert rec.stage == "l3" and rec.rejection == ""


def test_cascade_l0_rejection_stops_before_l2(hw):
    mesh = make_mesh((1,), ("x",))
    entry = next(e for e in mutation_corpus()
                 if e["cls"] == "dropped_signal")
    bad_report = entry["run"]()

    class Sabotaged(CascadeEvaluator):
        def _verify_l0(self, d):
            return bad_report

    ev = Sabotaged(ToyWorkload(), mesh, hw)
    l2_calls = {"n": 0}
    orig = ev._run_l2

    def counting(jfn):
        l2_calls["n"] += 1
        return orig(jfn)

    ev._run_l2 = counting
    res = ev.evaluate(Candidate(directive=CONSERVATIVE))
    assert res.level == 0 and res.score == 0.0
    assert res.rejection == "l0:deadlock"
    assert res.diagnostic.startswith("l0 schedule verify failed")
    assert "deadlock" in res.diagnostic
    assert l2_calls["n"] == 0                 # l0 rejected, l2 never ran
    rec = res.record
    assert rec.stage == "l0"
    assert "l0" in rec.levels_s
    assert "l1" not in rec.levels_s and "l2" not in rec.levels_s


def test_cascade_invalid_directive_tagged(hw):
    mesh = make_mesh((1,), ("x",))

    class Picky(ToyWorkload):
        def check(self, d, hw=None):
            return ["toy rejects everything"]

    ev = CascadeEvaluator(Picky(), mesh, hw)
    res = ev.evaluate(Candidate(directive=CONSERVATIVE))
    assert res.level == 0 and res.rejection == "invalid"
    assert res.record.rejection == "invalid"


def test_quarantine_entry_names_stage_in_flight(hw):
    mesh = make_mesh((1,), ("x",))
    w = ToyWorkload(sleep_s=5.0)
    ev = CascadeEvaluator(w, mesh, hw, timeout_s=0.5)
    res = ev.evaluate(Candidate(directive=Directive(
        "PALLAS_RDMA", "SIGNAL", "TILE_FUSED")))
    assert res.quarantined and res.rejection == "quarantine"
    entry = ev.quarantine_report()[0]
    assert entry["stage"] in ("l0", "l1", "l2", "l3")
    assert f"at {entry['stage']}" in res.diagnostic
    assert res.record.rejection == "quarantine"


# ------------------------------------------------- telemetry record fields


def test_eval_record_rejection_round_trips_stage_stays_out_of_parity():
    rec = EvalRecord(cid=7, level=0, score=0.0, rejection="l0:slot-reuse",
                     stage="l0", levels_s={"l0": 0.01},
                     diagnostic="l0 schedule verify failed: ...")
    back = EvalRecord.from_json(rec.to_json())
    assert back.rejection == "l0:slot-reuse" and back.stage == "l0"
    det = rec.deterministic_dict()
    assert det["rejection"] == "l0:slot-reuse"
    assert "stage" not in det and "levels_s" not in det


# --------------------------------------------- hypothesis property (fuzz)


def test_property_sanitized_schedules_pass_l0():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(
        kind=st.sampled_from(["dispatch", "broadcast", "ring"]),
        n=st.integers(min_value=2, max_value=5),
        size=st.integers(min_value=1, max_value=200),
        tile=st.sampled_from([8, 16, 32, 64]),
        flag=st.booleans(),
        cx=st.sampled_from(tuple(TUNABLES["contexts"])),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def prop(kind, n, size, tile, flag, cx, seed):
        if kind == "dispatch":
            counts = tuple((seed * (i + 3) + size) % 97 for i in range(n))
            sched = make_schedule(counts, max(1, tile // 2), flag)
        elif kind == "broadcast":
            sched = make_broadcast_schedule(n, max(size, 1), tile, flag)
        else:
            sched = make_ring_schedule(n, max(size, 1), tile, flag)
        rep = verify_schedule(sched, contexts=(cx,))
        assert rep.ok, rep.summary()
        if sched.n > 2:
            live = tuple(r for r in range(sched.n) if r != sched.n - 1)
            rep = verify_schedule(sched.degrade(live), contexts=(cx,),
                                  parent=sched, live=live)
            assert rep.ok, rep.summary()

    prop()


def test_report_merge_dedupes_and_truncates():
    prog = lower_ring(make_ring_schedule(4, 64, 32, True), 2)
    mut = apply_mutation(prog, "premature_slot_reuse")
    r1, r2 = verify_program(mut), verify_program(mut)
    merged = VerifyReport.merge([r1, r2], subject="dup")
    assert not merged.ok
    assert len(merged.errors) == len(r1.errors)   # identical rows deduped
    assert merged.subject == "dup"
