"""Multi-device suites (remote-DMA kernels, workload directive equivalence,
sharded model paths, CUCo end-to-end). These need simulated host devices, and
jax pins the device count at first init — so each suite runs in a subprocess
with XLA_FLAGS set. The scripts live in tests/scripts/."""
import os
import pathlib
import subprocess
import sys


SCRIPTS = pathlib.Path(__file__).parent / "scripts"
SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_script(name, devices=4, timeout=1500, args=()):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(SCRIPTS / name), *args],
                          env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"{name} failed\nSTDOUT:\n{proc.stdout[-4000:]}\n"
        f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


def test_ring_attention_kernel_sweep():
    out = run_script("ring_kernel_suite.py")
    assert "ALL OK" in out


def test_collective_kernels():
    out = run_script("collective_kernels_suite.py")
    assert "ALL OK" in out


def test_gemm_allgather_8rank():
    """The executable counterpart of the fig6 sweep at a wider mesh
    (ROADMAP open item): the collective suite's budget-capped path at 8
    simulated ranks — FLUX + DEFERRED broadcast cascades to l3, fused and
    deferred numerics vs the oracle."""
    out = run_script("collective_kernels_suite.py", devices=8,
                     args=["--n-dev", "8"])
    assert "ALL OK" in out
    assert "flux l3 ok at 8 ranks" in out


def test_workload_directives_verify():
    out = run_script("workload_suite.py")
    assert "ALL OK" in out


def test_moe_dispatch_deepep_kernel():
    out = run_script("moe_dispatch_suite.py")
    assert "ALL OK" in out


def test_moe_dispatch_8rank():
    """The executable counterpart of the fig4 --n-dev 8 analytic sweep
    (ROADMAP open item): the suite's budget-capped path at 8 simulated
    ranks — Table-3 validity, DeepEP + FLUX cascades to l3, kernel
    numerics, tight-wire accounting."""
    out = run_script("moe_dispatch_suite.py", devices=8,
                     args=["--n-dev", "8"])
    assert "ALL OK" in out
    assert "flux l3 ok at 8 ranks" in out


def test_fault_suite(tmp_path):
    """Degraded-mode schedules under injected faults: every workload's
    dropped-peer plan cascades to l3 on the surviving mesh, wire faults
    are classified (not crashed on), a wedged candidate quarantines, and
    the healthy-vs-degraded benchmark artifact is emitted."""
    out_json = tmp_path / "BENCH_faults.json"
    out = run_script("fault_suite.py", args=["--out", str(out_json)])
    assert "ALL OK" in out
    import json
    bench = json.loads(out_json.read_text())
    assert set(bench["workloads"]) == {"moe_dispatch", "ring_attention",
                                       "gemm_allgather", "kv_transfer"}
    for entry in bench["workloads"].values():
        assert entry["degraded_ms"] > entry["healthy_ms"] > 0.0


def test_telemetry_suite(tmp_path):
    """Observability layer end to end: the short telemetry search, one
    Perfetto timeline per workload (critical path == analytic_cost), the
    observed-vs-modeled ScheduleProbe check — and the regenerated
    BENCH_search.json must match the checked-in artifact byte for byte
    (the search is deterministic; a diff means the search or its
    telemetry changed and the artifact needs re-checking-in)."""
    out_json = tmp_path / "BENCH_search.json"
    out = run_script("telemetry_suite.py", args=["--out", str(out_json)])
    assert "ALL OK" in out
    import json
    regen = json.loads(out_json.read_text())
    assert regen["schema"] == "bench-search/v2"
    checked_in = pathlib.Path(__file__).parents[1] / "BENCH_search.json"
    assert json.loads(checked_in.read_text()) == regen, (
        "regenerate with: XLA_FLAGS=--xla_force_host_platform_device_count=4 "
        "PYTHONPATH=src python tests/scripts/telemetry_suite.py")


def test_search_scale_suite(tmp_path):
    """Scaled search end to end: batched ring_attention parity at 4 ranks,
    gemm_allgather warm-start economics (cold best reached in <= half the
    fresh evaluations), gemm_allgather -> moe_dispatch transfer seeding —
    and the regenerated BENCH_search_scale.json must match the checked-in
    artifact byte for byte (the searches are deterministic; a diff means
    the search changed and the artifact needs re-checking-in)."""
    out_json = tmp_path / "BENCH_search_scale.json"
    out = run_script("search_scale_suite.py", args=["--out", str(out_json)])
    assert "ALL OK" in out
    import json
    regen = json.loads(out_json.read_text())
    assert regen["schema"] == "bench-search-scale/v1"
    w = regen["warm_start"]
    assert w["warm_fresh_evals_to_best"] <= w["cold_evals_to_best"] // 2
    assert w["coverage_resumed"] >= w["coverage_saved"]
    x = regen["transfer"]
    assert x["transferred_seeds"] > 0
    assert x["transfer_fresh_evals_to_best"] <= x["cold_evals_to_best"] // 2
    checked_in = pathlib.Path(__file__).parents[1] / "BENCH_search_scale.json"
    assert json.loads(checked_in.read_text()) == regen, (
        "regenerate with: XLA_FLAGS=--xla_force_host_platform_device_count=4 "
        "PYTHONPATH=src python tests/scripts/search_scale_suite.py")


def test_serving_suite(tmp_path):
    """Kernelized serving tier end to end: the serving_step overlap points
    cascade to l3, the two-stream kernel issues the shared-expert FFN
    inside the dispatch send window, the engine's pallas decode matches
    host greedy tokens through continuous batching, the cache handoff
    rides kv_shuttle, a mid-run rank drop keeps serving — and the
    regenerated BENCH_serving.json must match the checked-in artifact
    (the rows are modeled, hence deterministic; a diff means the cost
    model changed and the artifact needs re-checking-in)."""
    out_json = tmp_path / "BENCH_serving.json"
    out = run_script("serving_suite.py", args=["--out", str(out_json)])
    assert "ALL OK" in out
    import json
    regen = json.loads(out_json.read_text())
    assert regen["schema"] == "bench-rows/v1"
    checked_in = pathlib.Path(__file__).parents[1] / "BENCH_serving.json"
    assert json.loads(checked_in.read_text()) == regen, (
        "regenerate with: XLA_FLAGS=--xla_force_host_platform_device_count=4 "
        "PYTHONPATH=src python tests/scripts/serving_suite.py")


def test_sharded_model_equivalence():
    out = run_script("sharded_model_suite.py", devices=8)
    assert "ALL OK" in out


def test_cuco_end_to_end():
    out = run_script("cuco_suite.py")
    assert "ALL OK" in out


def test_collective_helpers():
    out = run_script("collectives_suite.py", devices=8)
    assert "ALL OK" in out


def test_schedule_opts_semantics_preserving():
    out = run_script("schedule_opts_suite.py", devices=8)
    assert "ALL OK" in out
