"""HLO collective parser + roofline model unit tests."""
import pytest

from repro.core.cost_model import (CollectiveOp, RooflineReport,
                                   parse_collectives, _wire_factor)
from repro.core.hardware import V5E

HLO = """
HloModule test
%psum.1 = f32[16,4096,2048]{2,1,0} all-reduce(f32[16,4096,2048]{2,1,0} %x), replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
%ag.1 = bf16[256,1024]{1,0} all-gather(bf16[16,1024]{1,0} %y), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
%rs = bf16[16,1024]{1,0} reduce-scatter(bf16[256,1024]{1,0} %z), replica_groups=[1,512]<=[512], dimensions={0}
%a2a-start = (bf16[32,128,64]{2,1,0}, bf16[32,128,64]{2,1,0}) all-to-all-start(bf16[32,128,64]{2,1,0} %w), replica_groups=[16,32]<=[512]
%cp = f32[8,128]{1,0} collective-permute(f32[8,128]{1,0} %v), source_target_pairs={{0,1},{1,2}}
%prom = bf16[4,4]{1,0} all-reduce(bf16[4,4]{1,0} %u), replica_groups=[2,2]<=[4], to_apply=%add.clone_promoted
"""


def test_parse_finds_all_kinds():
    ops = parse_collectives(HLO, chips_per_pod=256)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]


def test_wire_factors():
    assert _wire_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
    assert _wire_factor("all-gather", 16) == pytest.approx(15 / 16)
    assert _wire_factor("collective-permute", 2) == 1.0
    assert _wire_factor("all-reduce", 1) == 0.0


def test_payload_and_groups():
    ops = {o.kind + str(i): o for i, o in
           enumerate(parse_collectives(HLO, chips_per_pod=256))}
    ar = [o for o in ops.values() if o.kind == "all-reduce"][0]
    assert ar.payload_bytes == 16 * 4096 * 2048 * 4
    assert ar.group_size == 16
    assert not ar.crosses_pod
    rs = [o for o in ops.values() if o.kind == "reduce-scatter"][0]
    assert rs.group_size == 512
    assert rs.crosses_pod                  # group of 512 spans two 256-pods
    ag = [o for o in ops.values() if o.kind == "all-gather"][0]
    assert ag.group_size == 16
    assert ag.payload_bytes == 256 * 1024 * 2   # result side is the payload


def test_promoted_bf16_correction():
    ops = parse_collectives(HLO, chips_per_pod=256)
    prom = [o for o in ops if o.payload_bytes == 4 * 4 * 2][0]
    # f32-promoted on CPU -> charged at half (bf16 on TPU wire)
    assert prom.wire_bytes == pytest.approx(
        prom.payload_bytes * _wire_factor("all-reduce", 2) * 0.5)


def test_roofline_terms_and_dominance():
    rep = RooflineReport(
        flops=197e12, bytes_accessed=819e9 / 2,
        collectives=[CollectiveOp("all-reduce", 10 * 2**30, 16, False,
                                  wire_bytes=100e9)])
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(0.5)
    assert rep.collective_s == pytest.approx(100e9 / V5E.ici_link_bw)
    assert rep.dominant == "collective"
    assert rep.step_time_s == pytest.approx(rep.collective_s)
    assert rep.serial_time_s == pytest.approx(
        rep.compute_s + rep.memory_s + rep.collective_s)


def test_dcn_charged_at_dcn_bw():
    rep = RooflineReport(flops=0, bytes_accessed=0, collectives=[
        CollectiveOp("all-reduce", 0, 512, True, wire_bytes=25e9)])
    assert rep.collective_s == pytest.approx(1.0)   # 25 GB at 25 GB/s


def test_extrapolate_linear():
    r1 = RooflineReport(flops=10.0, bytes_accessed=100.0, collectives=[
        CollectiveOp("all-reduce", 8, 4, False, 4.0)])
    r2 = RooflineReport(flops=14.0, bytes_accessed=130.0, collectives=[
        CollectiveOp("all-reduce", 8, 4, False, 4.0),
        CollectiveOp("all-gather", 16, 4, False, 12.0)])
    r = r1.extrapolate(r2, repeats=5)
    assert r.flops == pytest.approx(10 + 4 * 4)
    assert r.bytes_accessed == pytest.approx(100 + 4 * 30)
    # body all-gather appears 4 extra times
    assert len(r.collectives) == 1 + 4
    assert sum(c.wire_bytes for c in r.collectives) == pytest.approx(
        4.0 + 4 * 12.0)
