"""Serving tier: the two-stream decode step and the continuous-batching
scheduler/engine loop.

1. The ``serving_step`` workload's l3 model: every expert-system overlap
   point costs no more than the sequential host step, the FLUX point is
   the kernel two-stream path, and each point's timeline critical path
   equals ``analytic_cost``.
2. The TokenWeave and FLUX directives are *executable* for the serving
   step (no design-space violations), not just modelable.
3. Scheduler invariants: the per-step token budget is never exceeded,
   admission is FIFO, nothing starves, every request completes, and the
   policy is deterministic.
4. The engine serve loop: per-request sampling streams are independent of
   batch composition, and a re-seeded engine replays them exactly.

Kernelized 4-rank serving (pallas decode parity, degraded-mode serve, the
benchmark artifact) runs in ``tests/scripts/serving_suite.py`` via
``tests/test_multidevice.py``; the device-gated tests here skip cleanly
on hosts with fewer than 4 devices.
"""
import random

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import CONSERVATIVE, extract_hardware_context
from repro.core.design_space import EXPERT_SYSTEMS
from repro.core.trace import schedule_timeline, validate_trace
from repro.launch.mesh import make_mesh
from repro.models import StepOptions, init_params
from repro.serve import Engine, Request, Scheduler, ServeConfig
from repro.workloads import get_workload

needs_4dev = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4); covered by tests/scripts/serving_suite.py")


# ---------------------------------------------------------------- l3 model

def _serving_hw():
    return extract_hardware_context(make_mesh((1,), ("x",)))


def test_two_stream_points_executable_and_no_worse_than_host():
    w = get_workload("serving_step")
    hw = _serving_hw()
    host = w.analytic_cost(CONSERVATIVE, hw)
    for name, d in EXPERT_SYSTEMS.items():
        assert w.check(d, hw) == [], (name, w.check(d, hw))
        cost = w.analytic_cost(d, hw)
        assert cost <= host, (name, cost, host)


def test_flux_two_stream_overlap_credit():
    """The overlap credit is exactly the min of the two streams: the span
    segment is max(wire, compute), never less than either stream and never
    more than their sum (the sequential bound)."""
    w = get_workload("serving_step")
    hw = _serving_hw()
    bd = w.cost_breakdown(EXPERT_SYSTEMS["FLUX"], hw)
    assert bd.meta["path"] == "kernel_two_stream"
    span = next(s for s in bd.segments if s.name == "two_stream_span")
    wire, comp = span.meta["wire_s"], span.meta["compute_s"]
    assert abs(span.dur_s - max(wire, comp)) < 1e-12
    assert max(wire, comp) <= span.dur_s <= wire + comp
    # host path has no overlap segment: it is a strict sum
    host_bd = w.cost_breakdown(CONSERVATIVE, hw)
    assert host_bd.meta["path"] == "xla_host"
    assert not any(s.kind == "overlap" for s in host_bd.segments)
    # TokenWeave hides dispatch behind the shared + self-chunk FFNs
    tw = w.cost_breakdown(EXPERT_SYSTEMS["TokenWeave"], hw)
    assert tw.meta["path"] == "xla_two_stream"
    assert any(s.kind == "overlap" for s in tw.segments)


def test_serving_timeline_critical_path_matches_analytic_cost():
    w = get_workload("serving_step")
    hw = _serving_hw()
    for d in (CONSERVATIVE, EXPERT_SYSTEMS["TokenWeave"],
              EXPERT_SYSTEMS["FLUX"]):
        tl = schedule_timeline(w, d, hw)
        assert validate_trace(tl.to_dict()) > 0
        expect = w.analytic_cost(d, hw)
        assert abs(tl.critical_path_s - expect) < 1e-6, (
            d.backend, tl.critical_path_s, expect)


# ---------------------------------------------------------------- scheduler

def _sim(seed, token_budget=12, max_batch=3, n_req=20):
    """Run the pure scheduler policy to completion; returns the per-step
    plans and bookkeeping for invariant checks."""
    rng = random.Random(seed)
    s = Scheduler(token_budget=token_budget, max_batch=max_batch)
    plen = min(10, token_budget + 1)
    reqs = [Request(i, tuple(rng.randrange(50)
                             for _ in range(rng.randrange(1, plen))),
                    max_new_tokens=rng.randrange(1, 6)) for i in range(n_req)]
    for r in reqs:
        s.submit(r)
    decoded = {r.rid: 0 for r in reqs}
    plans, admit_order, last_served = [], [], {}
    steps = 0
    while s.pending:
        dec, adm = s.plan_step()
        plans.append((tuple(dec), tuple(r.rid for r in adm)))
        used = len(dec) + sum(r.prompt_len for r in adm)
        assert used <= s.token_budget, (steps, used)
        admit_order += [r.rid for r in adm]
        for rid in dec + [r.rid for r in adm]:
            decoded[rid] += 1           # admission emits the first token
            last_served[rid] = steps
        for rid in list(s.active):
            if decoded[rid] >= s.active[rid].max_new_tokens:
                s.finish(rid)
        for rid in s.active:            # no active request goes unserved
            assert steps - last_served.get(rid, steps) <= len(s.active)
        steps += 1
        assert steps < 10 * n_req
    assert admit_order == sorted(admit_order)          # FIFO admission
    assert all(decoded[r.rid] == r.max_new_tokens for r in reqs)
    return plans


def test_scheduler_budget_fifo_starvation_free():
    for seed in range(4):
        _sim(seed)
    # budget smaller than the active set still rotates fairly
    _sim(1, token_budget=2, max_batch=8)


def test_scheduler_deterministic():
    assert _sim(0) == _sim(0)


def test_scheduler_rejections():
    s = Scheduler(token_budget=4)
    with pytest.raises(ValueError):
        s.submit(Request(0, (1,) * 5))         # prompt can never fit
    s.submit(Request(1, (1, 2)))
    with pytest.raises(ValueError):
        s.submit(Request(1, (3,)))             # duplicate rid
    with pytest.raises(ValueError):
        Request(2, ())                         # empty prompt
    with pytest.raises(ValueError):
        Request(3, (1,), max_new_tokens=0)


# ------------------------------------------------------------- serve loop

def _requests(cfg, n=5):
    rng = random.Random(1)
    return [Request(i, tuple(rng.randrange(cfg.vocab_size)
                             for _ in range(3 + i % 3)),
                    max_new_tokens=2 + i % 4) for i in range(n)]


def test_serve_streams_independent_of_batch_composition():
    """A request's sampled tokens depend only on (seed, rid), not on which
    other requests shared its batches — the per-request ``fold_in`` stream
    regression for continuous batching (reassembled batches must not bleed
    into each other's samples)."""
    cfg = reduced(get_arch("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_seq=32, temperature=0.7, seed=3)

    eng = Engine(cfg, params, scfg)
    s = Scheduler(token_budget=8, max_batch=3)
    for r in _requests(cfg):
        s.submit(r)
    out = eng.serve(s)
    assert sorted(out) == list(range(5))
    assert [len(out[r]) for r in sorted(out)] == [2, 3, 4, 5, 2]

    # replay: same seed, same stream
    eng2 = Engine(cfg, params, scfg)
    s2 = Scheduler(token_budget=8, max_batch=3)
    for r in _requests(cfg):
        s2.submit(r)
    out2 = eng2.serve(s2)
    assert all(np.array_equal(out[r], out2[r]) for r in out)

    # serve one request alone: identical tokens despite different batching
    eng3 = Engine(cfg, params, scfg)
    s3 = Scheduler(token_budget=8, max_batch=1)
    s3.submit(_requests(cfg)[2])
    out3 = eng3.serve(s3)
    assert np.array_equal(out3[2], out[2])


def test_serve_metrics_accounting():
    cfg = reduced(get_arch("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_seq=32))
    s = Scheduler(token_budget=8, max_batch=2, metrics=eng.metrics)
    reqs = _requests(cfg, n=3)
    for r in reqs:
        s.submit(r)
    out = eng.serve(s)
    c = eng.metrics.snapshot()["counters"]
    assert c["sched.submitted"] == 3 and c["sched.finished"] == 3
    assert c["serve.prefills"] == 3
    total = sum(len(v) for v in out.values())
    assert c["serve.tokens_generated"] == total - 3   # first tokens: prefill
    assert c["serve.prefill_tokens"] == sum(r.prompt_len for r in reqs)


@needs_4dev
def test_serve_kernelized_decode_parity_4dev():
    """Engine decode through the fused moe_dispatch kernel (FLUX point,
    ``StepOptions(moe_backend="pallas", moe_overlap=True)``) emits exactly
    the host path's greedy tokens."""
    from repro.compat import make_mesh as compat_mesh
    from repro.dist.sharding import Rules
    cfg = reduced(get_arch("llama4-maverick-400b-a17b"), num_experts=4,
                  experts_per_token=1, pad_to=2, capacity_factor=16.0)
    rules = Rules(compat_mesh((4,), ("data",)), "decode")
    params = init_params(jax.random.PRNGKey(0), cfg)

    def run(opts):
        eng = Engine(cfg, params, ServeConfig(max_seq=32, opts=opts),
                     rules=rules)
        s = Scheduler(token_budget=16, max_batch=4)
        for i in range(4):
            s.submit(Request(i, (1 + i, 2 + i, 3 + i, 4 + i),
                             max_new_tokens=3))
        return eng.serve(s)

    host = run(StepOptions(remat=False))
    pal = run(StepOptions(remat=False, moe_backend="pallas",
                          moe_overlap=True))
    assert all(np.array_equal(host[r], pal[r]) for r in host)
