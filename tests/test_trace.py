"""Observability layer (core/trace.py, core/telemetry.py).

Tier-1 coverage that needs no simulated devices:
  * TraceWriter emits structurally valid Chrome-trace/Perfetto JSON and
    ``validate_trace`` rejects malformed events;
  * **the invariant**: for every workload x (FLUX, CONSERVATIVE) the
    rendered ``schedule_timeline`` critical path equals ``analytic_cost``
    within 1e-6 — and with a fault plan, ``fault_cost``;
  * degraded timelines (``live_ranks`` / plan splices) render and stay
    valid, including kv_transfer collapsing to its solo shape;
  * EvalRecord JSON round-trips exactly (non-finite -> null);
  * MetricsRegistry histogram quantiles + the ElasticController /
    serve-engine metric names;
  * a hypothesis property (skips when hypothesis is absent, matching
    test_schedules.py): replayed send-window depths never exceed the
    ``contexts`` cap for any schedule shape.

The executable 4-rank probe counterpart (observed DMA order vs the
trace-time schedule) lives in tests/scripts/telemetry_suite.py.
"""
import json

import pytest

from repro.core import extract_hardware_context
from repro.core.design_space import CONSERVATIVE, EXPERT_SYSTEMS, Directive
from repro.core.faults import (DROPPED_PEER, STRAGGLER, FaultPlan, FaultSpec,
                               fault_cost)
from repro.core.schedule import (make_broadcast_schedule, make_ring_schedule,
                                 make_schedule)
from repro.core.telemetry import EvalRecord, MetricsRegistry, SearchTelemetry
from repro.core.trace import (TraceWriter, schedule_timeline, validate_trace)
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload

WORKLOAD_NAMES = ("moe_dispatch", "ring_attention", "gemm_allgather",
                  "kv_transfer")
FLUX = EXPERT_SYSTEMS["FLUX"]


@pytest.fixture(scope="module")
def hw():
    return extract_hardware_context(make_mesh((1,), ("x",)))


# ------------------------------------------------------------ trace schema


def test_trace_writer_emits_valid_perfetto_json():
    w = TraceWriter()
    w.meta_process(0, "rank 0")
    w.meta_thread(0, 0, "critical path")
    w.span("gemm", 0.0, 120.5, pid=0, tid=0, args={"kind": "compute"})
    w.counter("send window", 10.0, {"in_flight": 2}, pid=0)
    w.instant("dma issue (1,0)", 12.0, pid=0, tid=1)
    obj = json.loads(w.to_json())
    assert obj["displayTimeUnit"] == "ms"
    assert validate_trace(obj) == 5
    phases = [e["ph"] for e in obj["traceEvents"]]
    assert phases == ["M", "M", "X", "C", "i"]


def test_validate_trace_rejects_malformed_events():
    with pytest.raises(ValueError):
        validate_trace({"events": []})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "Z", "name": "x"}]})
    with pytest.raises(ValueError):          # span missing dur
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "x", "ts": 0.0, "pid": 0, "tid": 0}]})
    with pytest.raises(ValueError):          # negative ts
        validate_trace({"traceEvents": [
            {"ph": "i", "name": "x", "ts": -1.0, "pid": 0, "tid": 0,
             "s": "t"}]})


# ------------------------------------------- the critical-path invariant


@pytest.mark.parametrize("name", WORKLOAD_NAMES)
@pytest.mark.parametrize("directive", [FLUX, CONSERVATIVE],
                         ids=["flux", "conservative"])
def test_timeline_critical_path_equals_analytic_cost(name, directive, hw):
    """The tentpole invariant: the rendered timeline audits exactly the
    scalar the cascade scores."""
    w = get_workload(name)
    tl = schedule_timeline(w, directive, hw)
    expect = w.analytic_cost(directive, hw)
    assert tl.critical_path_s == pytest.approx(expect, abs=1e-6)
    assert not tl.degraded
    n_events = validate_trace(tl.to_dict())
    assert n_events > 0
    # kernelized directives attach the schedule detail tracks
    if tl.breakdown.schedule is not None:
        cats = {e.get("cat") for e in tl.to_dict()["traceEvents"]}
        assert "dma" in cats


@pytest.mark.parametrize("name", WORKLOAD_NAMES)
def test_timeline_spans_match_breakdown_segments(name, hw):
    w = get_workload(name)
    tl = schedule_timeline(w, FLUX, hw)
    spans = [e for e in tl.to_dict()["traceEvents"]
             if e["ph"] == "X" and e["pid"] == 0 and e["tid"] == 0]
    # every positive-duration segment appears, back to back, in order
    expect = [s for s in tl.breakdown.segments if s.dur_s > 0]
    assert [e["name"] for e in spans] == [s.name for s in expect]
    cursor = 0.0
    for ev in spans:
        assert ev["ts"] >= cursor - 1e-9
        cursor = ev["ts"] + ev["dur"]
    assert cursor * 1e-6 == pytest.approx(tl.critical_path_s, abs=1e-6)


@pytest.mark.parametrize("name", ("moe_dispatch", "ring_attention",
                                  "gemm_allgather"))
def test_degraded_timeline_renders(name, hw):
    w = get_workload(name)
    live = tuple(range(w.n_dev))[:-1]
    tl = schedule_timeline(w, FLUX, hw, live_ranks=live)
    assert tl.degraded and tl.live_ranks == live
    validate_trace(tl.to_dict())
    degraded = w.degrade(live)
    assert tl.critical_path_s == pytest.approx(
        degraded.analytic_cost(FLUX, hw), abs=1e-6)


def test_kv_transfer_degrades_to_solo_timeline(hw):
    w = get_workload("kv_transfer")
    tl = schedule_timeline(w, FLUX, hw, live_ranks=(0,))
    assert tl.degraded
    validate_trace(tl.to_dict())
    assert tl.critical_path_s == pytest.approx(
        w.degrade((0,)).analytic_cost(FLUX, hw), abs=1e-6)


@pytest.mark.parametrize("name", WORKLOAD_NAMES)
def test_fault_plan_timeline_equals_fault_cost(name, hw):
    """With a plan the splice order mirrors fault_cost exactly: degraded
    analytic + state recovery + remesh + straggler stall."""
    w = get_workload(name)
    faults = [FaultSpec(STRAGGLER, rank=0, rounds=8, delay_s=50e-6)]
    if w.n_dev > 2:
        faults.append(FaultSpec(DROPPED_PEER, rank=1))
    plan = FaultPlan("trace-plan", tuple(faults))
    tl = schedule_timeline(w, FLUX, hw, plan=plan)
    expect = fault_cost(w, FLUX, hw, plan)
    assert tl.critical_path_s == pytest.approx(expect, abs=1e-6)
    names = [e["name"] for e in tl.to_dict()["traceEvents"]
             if e["ph"] == "X" and e["pid"] == 0]
    assert "straggler_stall" in names
    if w.n_dev > 2:
        assert "state_recovery" in names and "remesh" in names
    with pytest.raises(ValueError):
        schedule_timeline(w, FLUX, hw, plan=plan, live_ranks=(0,))


def test_timeline_writes_loadable_file(tmp_path, hw):
    w = get_workload("gemm_allgather")
    path = tmp_path / "timeline.json"
    schedule_timeline(w, FLUX, hw).write(str(path))
    validate_trace(json.loads(path.read_text()))


# --------------------------------------------------------------- telemetry


def test_eval_record_json_round_trip_is_exact():
    rec = EvalRecord(cid=7, gen=3, island=1, mutation="coarse",
                     directive="Directive(...)", level=3, score=812.5,
                     t_model_ms=11.3, t_wall_ms=None,
                     levels_s={"l1": 0.5, "l2": 1.25, "l3": 0.002},
                     retries=1, quarantined=False, fault_penalty_ms=2.0,
                     knobs={"contexts": 2, "tile_m": 128},
                     diagnostic="ok", elapsed_s=1.752)
    assert EvalRecord.from_json(rec.to_json()) == rec
    # non-finite never reaches JSON: it maps to null and stays None
    inf = EvalRecord(t_model_ms=float("inf"), t_wall_ms=float("nan"))
    back = EvalRecord.from_json(inf.to_json())
    assert back.t_model_ms is None and back.t_wall_ms is None
    assert "Infinity" not in inf.to_json() and "NaN" not in inf.to_json()


def test_search_telemetry_series_and_payload():
    tel = SearchTelemetry(workload="gemm_allgather")
    for gen in range(3):
        for i, score in enumerate((1.0, 10.0 * (gen + 1))):
            tel.observe(EvalRecord(cid=gen * 2 + i, gen=gen, island=i,
                                   mutation="coarse" if i else "fine",
                                   level=3, score=score))
        tel.note_coverage(gen, 0.1 * (gen + 1))
    gens = tel.generation_series()
    assert [g["gen"] for g in gens] == [0, 1, 2]
    assert gens[2]["best_score"] == 30.0
    assert gens[1]["archive_coverage"] == pytest.approx(0.2)
    assert {i["island"] for i in tel.island_series()} == {0, 1}
    stats = {m["mutation"]: m for m in tel.mutation_stats()}
    # "coarse" set a new global best every generation; the flat "fine"
    # stream only won the very first observation (1.0 beat the empty best)
    assert stats["coarse"]["wins"] == 3 and stats["fine"]["wins"] == 1
    payload = tel.payload(meta={"generations": 3})
    assert payload["schema"] == "bench-search/v2"
    assert payload["totals"]["evals"] == 6
    assert payload["best"]["score"] == 30.0
    json.dumps(payload)                       # JSON-clean end to end


def test_metrics_registry_histogram_quantiles():
    m = MetricsRegistry()
    h = m.histogram("decode_step_ms")
    for v in range(1, 101):                   # 1..100
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == pytest.approx(50.5)
    assert s["p90"] == pytest.approx(90.1)
    assert s["p99"] == pytest.approx(99.01)
    m.counter("tokens").inc(8)
    m.gauge("live_ranks").set(3)
    snap = m.snapshot()
    assert snap["counters"]["tokens"] == 8
    assert snap["gauges"]["live_ranks"] == 3.0
    json.loads(m.to_json())


def test_histogram_decimation_bounds_memory():
    h = MetricsRegistry().histogram("h", max_samples=64)
    for v in range(1000):
        h.observe(float(v))
    assert len(h.samples) <= 64
    assert h.count == 1000 and h.total == sum(range(1000))
    assert h.quantile(1.0) >= 990.0           # tail survives decimation


def test_elastic_controller_exports_fleet_metrics():
    from repro.train.fault_tolerance import ElasticController
    ec = ElasticController(n_ranks=4, min_samples=2, replace_after=2,
                           threshold=1.5)
    for step in range(12):
        times = {r: 0.01 for r in ec.live_ranks}
        if step >= 4:
            times[3] = 0.1                    # persistent straggler
        ec.observe_round(times)
    snap = ec.metrics.snapshot()
    assert ec.live_ranks == (0, 1, 2)
    assert snap["gauges"]["elastic.live_ranks"] == 3.0
    assert snap["counters"]["elastic.ranks_dropped"] == 1.0
    assert snap["counters"]["elastic.straggler_incidents"] >= 2.0
    assert snap["histograms"]["elastic.step_ms"]["count"] > 0


# ------------------------------------------------------ hypothesis property

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                           # optional test dep: skip
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(kind=st.sampled_from(("dispatch", "broadcast", "ring")),
           n=st.integers(2, 8), size=st.integers(1, 512),
           contexts=st.integers(1, 4), data=st.data())
    def test_send_window_depth_never_exceeds_contexts(kind, n, size,
                                                      contexts, data):
        """The window-cap half of the ScheduleProbe contract, as a pure
        trace-time property over every schedule family."""
        if kind == "dispatch":
            counts = data.draw(st.lists(st.integers(0, 4 * size),
                                        min_size=n, max_size=n))
            sched = make_schedule(counts, block_tokens=max(1, size))
        elif kind == "broadcast":
            sched = make_broadcast_schedule(n, max(size, 1), tile_m=size)
        else:
            sched = make_ring_schedule(n, max(size, 1), kv_chunk=size)
        depths = sched.send_window_depths(contexts)
        assert len(depths) == len(list(sched.rounds))
        assert all(1 <= d <= contexts for d in depths)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_send_window_depth_never_exceeds_contexts():
        pass
