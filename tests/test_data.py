"""Data pipeline: determinism, host sharding, label shift, structure."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")       # optional test dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, SyntheticTokenPipeline


def _cfg(**kw):
    base = dict(vocab_size=97, seq_len=32, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = SyntheticTokenPipeline(_cfg()).batch(7)
    b = SyntheticTokenPipeline(_cfg()).batch(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])


def test_different_steps_differ():
    p = SyntheticTokenPipeline(_cfg())
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


@given(st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_host_sharding_partitions_global_batch(step):
    full = SyntheticTokenPipeline(_cfg()).batch(step)
    parts = [SyntheticTokenPipeline(_cfg(), host_index=h, num_hosts=4)
             .batch(step) for h in range(4)]
    reassembled = np.concatenate([p["tokens"] for p in parts])
    assert np.array_equal(full["tokens"], reassembled)


def test_labels_are_shifted_tokens():
    b = SyntheticTokenPipeline(_cfg()).batch(0)
    assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert np.all(b["labels"][:, -1] == -1)


def test_copy_structure_is_learnable():
    cfg = _cfg(copy_period=8, seq_len=64)
    b = SyntheticTokenPipeline(cfg).batch(0)
    t = b["tokens"]
    assert np.array_equal(t[:, 8:], t[:, :-8])      # period-8 copy structure


def test_vlm_and_encdec_stub_inputs():
    cfg = _cfg(frames=6, patches=4, d_model=16)
    b = SyntheticTokenPipeline(cfg).batch(0)
    assert b["frames"].shape == (8, 6, 16)
    assert b["patches"].shape == (8, 4, 16)
    assert np.all(b["labels"][:, :4] == -1)         # patch positions masked


def test_prefetch_matches_direct():
    p = SyntheticTokenPipeline(_cfg())
    p.start_prefetch(first_step=5)
    s, b = p.next_prefetched()
    p.stop()
    assert s == 5
    assert np.array_equal(b["tokens"], p.batch(5)["tokens"])
