"""Fault model + hardened evaluator (core/faults.py, core/cascade.py).

Tier-1 coverage of the degraded-mode contract that needs no devices:
  * ``respill_counts`` / ``degrade(live_ranks)`` trace-time semantics and
    their ValueError rules;
  * ``fault_cost``: for every workload a dropped-peer plan prices strictly
    greater than healthy but finite, and the straggler stall shrinks with
    deeper send windows (``window_stall_factor``);
  * ``survival_report`` -> ``EvalResult.fault_report`` plumbing and the
    ``fault_weight`` score trade-off;
  * the evaluator's wall-clock timeout/quarantine (a wedged candidate can
    never stall slow_path) and the one-retry-with-backoff l2 seam.
"""
import math
import time

import jax.numpy as jnp
import pytest

from repro.core import extract_hardware_context
from repro.core.cascade import Candidate, CascadeEvaluator
from repro.core.design_space import CONSERVATIVE, EXPERT_SYSTEMS, Directive
from repro.core.faults import (CORRUPT_WIRE, DROPPED_PEER, STRAGGLER,
                               TRUNCATED_WIRE, FaultPlan, FaultSpec,
                               fault_cost, inject_wire_fault,
                               survival_report)
from repro.core.schedule import (check_live, make_broadcast_schedule,
                                 make_ring_schedule, make_schedule,
                                 respill_counts)
from repro.launch.mesh import make_mesh
from repro.workloads import get_workload
from repro.workloads.base import Workload

WORKLOAD_NAMES = ("moe_dispatch", "ring_attention", "gemm_allgather",
                  "kv_transfer")


@pytest.fixture(scope="module")
def hw():
    return extract_hardware_context(make_mesh((1,), ("x",)))


# ------------------------------------------------------ respill / degrade

def test_respill_conserves_tokens_and_respects_capacity():
    counts = (100, 80, 60, 40)
    new = respill_counts(counts, (0, 1, 3))
    assert len(new) == 3
    assert sum(new) == sum(counts)
    cap = math.ceil(1.25 * sum(counts) / 3)
    assert max(new) <= cap
    # overflow beyond the capacity factor spreads uniformly, still conserving
    over = respill_counts((1000, 0), (1,), capacity_factor=1.25)
    assert over == (1000,)


def test_degrade_rejects_bad_membership():
    s = make_schedule((10, 10, 10, 10))
    with pytest.raises(ValueError):
        s.degrade(())
    with pytest.raises(ValueError):
        s.degrade((0, 4))
    with pytest.raises(ValueError):
        check_live((-1,), 4)
    assert s.degrade((0, 1, 2, 3)) is s


def test_schedule_degrade_is_smaller_same_class():
    d = make_schedule((100, 80, 60, 40), 64, True).degrade((0, 2, 3))
    assert d.n == 3 and sum(d.counts) == 280
    b = make_broadcast_schedule(4, 1024, 128, True).degrade((1, 2))
    assert (b.n, b.M_l, b.tile_m) == (2, 1024, 128)
    r = make_ring_schedule(4, 512, 64, True).degrade((0, 3))
    assert (r.n, r.steps, r.rows) == (2, 1, 512)


@pytest.mark.parametrize("name", WORKLOAD_NAMES)
def test_workload_degrade_reshapes(name):
    w = get_workload(name)
    live = tuple(range(w.n_dev - 1))
    dw = w.degrade(live)
    assert dw.n_dev == w.n_dev - 1
    assert type(dw) is type(w)
    assert w.degrade(tuple(range(w.n_dev))) is w
    with pytest.raises(ValueError):
        w.degrade(())


def test_moe_degrade_respills_routing():
    w = get_workload("moe_dispatch")
    counts = w._counts(w.T)
    dw = w.degrade((0, 1, 3))
    assert int(dw._counts(dw.T).sum()) == int(counts.sum())


# ----------------------------------------------------------- l3 charging

@pytest.mark.parametrize("name", WORKLOAD_NAMES)
@pytest.mark.parametrize("directive", [EXPERT_SYSTEMS["FLUX"], CONSERVATIVE],
                         ids=["flux", "conservative"])
def test_dropped_peer_costs_more_than_healthy_but_finite(name, directive,
                                                         hw):
    w = get_workload(name)
    plan = FaultPlan("drop1", (FaultSpec(DROPPED_PEER, rank=1),))
    healthy = w.analytic_cost(directive, hw)
    degraded = fault_cost(w, directive, hw, plan)
    assert math.isfinite(degraded)
    assert degraded > healthy


def test_straggler_stall_shrinks_with_window_depth(hw):
    w = get_workload("moe_dispatch")
    spec = FaultSpec(STRAGGLER, rank=1, rounds=16, delay_s=100e-6)
    plan = FaultPlan("strag", (spec,))
    shallow = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED",
                        contexts=1)
    deep = Directive("PALLAS_RDMA", "SIGNAL", "TILE_PIPELINED", contexts=4)
    stall_1 = fault_cost(w, shallow, hw, plan) \
        - w.analytic_cost(shallow, hw)
    stall_4 = fault_cost(w, deep, hw, plan) - w.analytic_cost(deep, hw)
    assert stall_1 == pytest.approx(16 * 100e-6)       # fully exposed
    assert stall_4 == pytest.approx(stall_1 / 4)       # window-absorbed


def test_plan_with_no_survivor_reports_not_survives(hw):
    w = get_workload("kv_transfer")
    plan = FaultPlan("all-dead", (FaultSpec(DROPPED_PEER, rank=0),
                                  FaultSpec(DROPPED_PEER, rank=1)))
    with pytest.raises(ValueError):
        fault_cost(w, CONSERVATIVE, hw, plan)
    rep = survival_report(w, CONSERVATIVE, hw, (plan,))
    assert not rep["all-dead"]["survives"]
    assert rep["all-dead"]["degraded_ms"] == float("inf")


def test_fault_spec_validates_kind():
    with pytest.raises(ValueError):
        FaultSpec("meteor-strike")


def test_inject_wire_fault_marks_output():
    out = (jnp.ones((8, 4)), jnp.ones((8, 4)))
    bad = inject_wire_fault(out, FaultSpec(CORRUPT_WIRE, rows=2))
    assert bool(jnp.isnan(bad[0][:2]).all())
    short = inject_wire_fault(out, FaultSpec(TRUNCATED_WIRE, rows=3))
    assert bool((short[1][-3:] == 0).all())
    assert bool((short[1][:-3] == 1).all())


# --------------------------------------- hardened evaluator (1-rank tier)

class ToyWorkload(Workload):
    """Minimal workload for evaluator-hardening tests: ``build`` wedges
    (sleeps at trace time) on one placement and is instant on the rest."""
    name = "toy"

    def __init__(self, n_dev=2, wedge_placement=None, sleep_s=5.0):
        self.n_dev = n_dev
        self.wedge_placement = wedge_placement
        self.sleep_s = sleep_s

    def check(self, d, hw=None):
        return []

    def example_inputs(self, key, mesh):
        return (jnp.ones((4, 4), jnp.float32),)

    def reference(self, x):
        return x * 2.0

    def build(self, d, mesh):
        if d.placement == self.wedge_placement:
            def wedged(x):
                time.sleep(self.sleep_s)      # wedges the trace
                return x * 2.0
            return wedged
        return lambda x: x * 2.0

    def analytic_cost(self, d, hw):
        return 1e-3 / self.n_dev

    def degrade(self, live_ranks):
        from repro.core.schedule import check_live
        live = check_live(live_ranks, self.n_dev)
        if len(live) == self.n_dev:
            return self
        return ToyWorkload(n_dev=len(live),
                           wedge_placement=self.wedge_placement)

    def state_bytes_per_rank(self):
        return 10 * 2**20


def test_evaluator_quarantines_wedged_candidate(hw):
    mesh = make_mesh((1,), ("x",))
    w = ToyWorkload(wedge_placement="TILE_FUSED", sleep_s=5.0)
    ev = CascadeEvaluator(w, mesh, hw, timeout_s=0.5)
    t0 = time.perf_counter()
    res = ev.evaluate(Candidate(directive=Directive(
        "PALLAS_RDMA", "SIGNAL", "TILE_FUSED")))
    assert time.perf_counter() - t0 < w.sleep_s      # did not wait it out
    assert res.quarantined and res.level == 0 and res.score == 0.0
    assert "quarantined" in res.diagnostic
    assert len(ev.quarantine_report()) == 1
    # the evaluator survives: the next (healthy) candidate reaches l3
    ok = ev.evaluate(Candidate(directive=Directive(
        "PALLAS_RDMA", "SIGNAL", "DEFERRED")))
    assert ok.ok and not ok.quarantined


def test_evaluator_retries_flaky_l2(hw):
    mesh = make_mesh((1,), ("x",))
    ev = CascadeEvaluator(ToyWorkload(), mesh, hw, backoff_s=0.0)
    orig = ev._run_l2
    calls = {"n": 0}

    def flaky(jfn):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient interpret hiccup")
        return orig(jfn)

    ev._run_l2 = flaky
    res = ev.evaluate(Candidate(directive=CONSERVATIVE))
    assert res.ok and res.retries == 1
    # a persistently failing execution still fails after the retry budget
    ev2 = CascadeEvaluator(ToyWorkload(), mesh, hw, backoff_s=0.0)

    def broken(jfn):
        raise RuntimeError("hard failure")

    ev2._run_l2 = broken
    res2 = ev2.evaluate(Candidate(directive=CONSERVATIVE))
    assert res2.level == 1 and res2.retries == 1
    assert "l2 execution failed" in res2.diagnostic


def test_evaluator_attaches_fault_report_and_prices_fragility(hw):
    mesh = make_mesh((1,), ("x",))
    plan = FaultPlan("drop1", (FaultSpec(DROPPED_PEER, rank=1),))
    base = CascadeEvaluator(ToyWorkload(), mesh, hw)
    res0 = base.evaluate(Candidate(directive=CONSERVATIVE))
    ev = CascadeEvaluator(ToyWorkload(), mesh, hw, fault_plans=(plan,),
                          fault_weight=1.0)
    res = ev.evaluate(Candidate(directive=CONSERVATIVE))
    assert res.ok
    entry = res.fault_report["drop1"]
    assert entry["survives"]
    assert entry["degraded_ms"] > entry["healthy_ms"]
    # the fault penalty is priced into the score, not just reported
    assert res.score < res0.score
    assert res.t_model_ms == res0.t_model_ms
